"""Path-based sharding rules (MaxText-style logical axes, resolved with
divisibility checks so every assigned architecture maps onto the fixed
(16, 16) / (2, 16, 16) production meshes without manual per-arch tables).

Axis roles:
  dp    : batch — ('pod', 'data') on the multi-pod mesh, ('data',) otherwise
  fsdp  : parameter sharding over 'data' (ZeRO-3 style; gathered on use by
          XLA SPMD). Pod axis intentionally excluded: across pods we run
          pure DP (params replicated per pod, gradients all-reduced over
          'pod' + 'data'), matching the ICI/DCI bandwidth hierarchy.
  tp    : tensor parallel over 'model'
  heads : tp, but only when the head count divides the axis (GQA models
          with few KV heads fall back to unsharded weights — attention then
          runs data-parallel while the FFN keeps full TP)
  ep    : expert parallel over 'model' when n_experts divides it, else
          experts stay TP inside each expert

Every role silently degrades to replication when the dim is not divisible
by the target axis — the rule engine never produces an invalid spec.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.types import PackedHiNM


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, name: str) -> int:
    # serving meshes may be data-only: a missing axis has size 0, which
    # every divisibility check below treats as "does not fit" (replicate)
    return mesh.shape[name] if name in mesh.axis_names else 0


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return False
    if isinstance(axis, tuple):
        n = int(np.prod([_axis_size(mesh, a) for a in axis]))
    else:
        n = _axis_size(mesh, axis)
    return n > 0 and dim % n == 0 and dim >= n


def _divides(n: int, mesh: Mesh, name: str) -> bool:
    """Like _fits but for count-divisibility checks (heads per shard)."""
    sz = _axis_size(mesh, name)
    return sz > 0 and n % sz == 0


def _resolve(role, dim: int, mesh: Mesh, cfg) -> Any:
    """role -> mesh axis name (or None), honouring divisibility."""
    if role is None:
        return None
    if role == "dp":
        ax = batch_axes(mesh)
        ax = ax if len(ax) > 1 else ax[0]
        return ax if _fits(dim, mesh, ax) else None
    if role == "fsdp":
        if (cfg is not None and getattr(cfg, "fsdp_pods", False)
                and "pod" in mesh.axis_names and _fits(dim, mesh, ("data", "pod"))):
            return ("data", "pod")
        return "data" if _fits(dim, mesh, "data") else None
    if role == "tp":
        return "model" if _fits(dim, mesh, "model") else None
    if role == "heads":
        return "model" if _fits(dim, mesh, "model") else None
    if role == "ep":
        return "model" if _fits(dim, mesh, "model") else None
    raise ValueError(role)


def _packed_spec(shape: tuple[int, ...], mesh: Mesh, cfg, field: str):
    """Specs for PackedHiNM array fields (layer-stacking dim already
    stripped by the caller). Tile dim T gets TP — tiles are independent
    (DESIGN.md §2). An expert-leading dim takes EP instead when it divides
    'model'."""
    ndim = len(shape)
    if field in ("vals", "nm_idx"):
        t_axis = ndim - 3
    else:  # vec_idx
        t_axis = ndim - 2
    spec = [None] * ndim
    if t_axis > 0 and _fits(shape[0], mesh, "model"):  # expert dim EP
        spec[0] = "model"
        if _fits(shape[-1], mesh, "data"):
            spec[-1] = "data"
        return P(*spec)
    # tiles are independent: prefer sharding T over BOTH axes (outputs are
    # tile-local, so no weight gather is ever needed); fall back to
    # T x model + trailing-dim x data (FSDP-style, gathered on use)
    from repro.perf_knobs import KNOBS

    if KNOBS.packed_t_axes == "both" and _fits(shape[t_axis], mesh, ("model", "data")):
        spec[t_axis] = ("model", "data")
    elif _fits(shape[t_axis], mesh, "model"):
        spec[t_axis] = "model"
        if KNOBS.packed_t_axes != "model_only" and _fits(shape[-1], mesh, "data"):
            spec[-1] = "data"
    return P(*spec)


def _rule_for(path: str, shape: tuple[int, ...], mesh: Mesh, cfg):
    """Return role tuple for a dense param leaf."""
    nd = len(shape)
    seg = path.split("/")
    key = seg[-1]
    parent = seg[-2] if len(seg) > 1 else ""

    def roles(*rs):
        return tuple(rs)

    if key == "table":
        from repro.perf_knobs import KNOBS

        # feature-sharded: the token gather's output is naturally
        # (batch, 'model')-sharded; vocab-sharding forces SPMD to fully
        # rematerialise the (B*S, D) gather output (§Perf iteration 1)
        if KNOBS.embed_feature_shard:
            return roles(None, "tp")
        return roles("tp", "fsdp")
    if key in ("scale",) or (key == "bias" and nd == 1 and parent.startswith("ln")):
        return (None,) * nd
    if key == "lam":
        return roles("tp")
    if key == "conv":
        return roles(None, "tp")
    if key == "r":
        return (None,) * nd
    if key == "b":
        base = (None,) * (nd - 1)
        # bias shards like its weight's output dim
        if parent in ("wq",):
            return base + ("heads" if cfg and _divides(cfg.n_heads, mesh, "model") else None,)
        if parent in ("wk", "wv"):
            return base + ("heads" if cfg and _divides(cfg.n_kv_heads, mesh, "model") else None,)
        if parent in ("wo", "wd", "wout"):
            return base + (None,)
        return base + ("tp",)
    if key != "w":
        return (None,) * nd

    # weight matrices: stored (n_in, n_out); expert stacks (E, n_in, n_out)
    lead = ()
    if nd == 3:  # expert stack
        if cfg and cfg.n_experts and _fits(shape[0], mesh, "model"):
            return ("ep", "fsdp", None)
        lead = (None,)
    if parent in ("wq",):
        out_role = "heads" if cfg and _divides(cfg.n_heads, mesh, "model") else None
        return lead + ("fsdp", out_role)
    if parent in ("wk", "wv"):
        out_role = "heads" if cfg and _divides(cfg.n_kv_heads, mesh, "model") else None
        return lead + ("fsdp", out_role)
    if parent == "wo":
        in_role = "heads" if cfg and _divides(cfg.n_heads, mesh, "model") else None
        return lead + (in_role, "fsdp")
    if parent in ("wd", "wout"):
        return lead + ("tp", "fsdp")
    if parent == "router":
        return lead + (None, None)
    if parent == "lm_head":
        return lead + ("fsdp", "tp")
    # default projection: shard output dim TP, input dim FSDP
    return lead + ("fsdp", "tp")


def _spec_for_leaf(path: str, leaf, mesh: Mesh, cfg) -> P:
    shape = tuple(leaf.shape)
    roles = _rule_for(path, shape, mesh, cfg)
    resolved = []
    used = set()
    for role, dim in zip(roles, shape):
        ax = _resolve(role, dim, mesh, cfg)
        # an axis may appear at most once in a spec
        if ax is not None and not isinstance(ax, tuple) and ax in used:
            ax = None
        if isinstance(ax, tuple) and any(a in used for a in ax):
            ax = None
        if ax is not None:
            used.update(ax if isinstance(ax, tuple) else (ax,))
        resolved.append(ax)
    # scan-stacked layer leading dim: roles computed for the layer shape
    return P(*resolved)


def param_specs(params, mesh: Mesh, cfg=None):
    """Pytree of PartitionSpec matching `params` (arrays or ShapeDtypeStructs).

    Scan-stacked leading dims (n_layers / pattern stacks) are detected by
    comparing path depth: any leaf under 'blocks'/'stacks'/'enc'/'dec' has a
    leading layer axis that is never sharded.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for pathkeys, leaf in flat:
        path = "/".join(_key_str(k) for k in pathkeys)
        stacked = any(s in path.split("/") for s in ("blocks", "enc", "dec", "stacks"))
        field = path.split("/")[-1]
        if field in ("vals", "vec_idx", "nm_idx"):
            inner_shape = tuple(leaf.shape[1:]) if stacked else tuple(leaf.shape)
            spec = _packed_spec(inner_shape, mesh, cfg, field)
            if stacked:
                spec = P(*((None,) + tuple(spec)))
            specs.append(spec)
            continue
        if stacked:
            # strip the layer axis, rule on the per-layer shape, re-prepend
            inner = jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
            spec = _spec_for_leaf(path, inner, mesh, cfg)
            spec = P(*((None,) + tuple(spec)))
        else:
            spec = _spec_for_leaf(path, leaf, mesh, cfg)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "name"):  # GetAttrKey (registered dataclass fields)
        return str(k.name)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k).lstrip(".")


def opt_state_specs(opt_state, pspecs):
    """Specs for optimizer state given param specs.

    AdamW: mu/nu mirror params. Adafactor: vr drops the last dim's axis,
    vc drops the second-to-last. count: replicated."""
    if "mu" in opt_state:
        return {
            "mu": pspecs,
            "nu": pspecs,
            "count": P(),
        }

    def fact(spec_leaf, state_leaf):
        spec = tuple(spec_leaf)
        if isinstance(state_leaf, dict) and "vr" in state_leaf:
            return {
                "vr": P(*spec[:-1]) if len(spec) > 1 else P(),
                "vc": P(*(spec[:-2] + spec[-1:])) if len(spec) > 1 else P(),
            }
        return {"v": P(*spec)}

    v = jax.tree.map(
        fact, pspecs, opt_state["v"],
        is_leaf=lambda x: isinstance(x, P),
    )
    return {"v": v, "count": P()}


def batch_specs(batch, mesh: Mesh):
    """Input batch: shard the leading (global batch) dim over dp axes."""
    dp = batch_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    def f(leaf):
        if leaf.ndim == 0:
            return P()
        if _fits(leaf.shape[0], mesh, dp):
            return P(dp, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(f, batch)


def _cache_leaf_spec(role: str, leaf, mesh: Mesh, dp) -> P:
    """Resolve one decode-cache leaf's sharding role to a PartitionSpec.

    Roles (declared per family by ``zoo.cache_shard_roles``):
      kv    : stripe K/V (L, B, S, KV, hd) — batch over dp, KV heads (or
              the slot dim, per KNOBS.decode_seq_shard) over 'model'
      page  : paged-pool leaf (L, n_pages, page, ...) — the PAGE axis over
              dp (the pool is a shared resource: its natural parallel axis
              is pages, not request slots), KV heads over 'model'
      slot  : per-slot bookkeeping (L, B[, ...]) — slot (batch) axis over
              dp so block-table/counter writes stay on the owning shard
      enc   : per-slot encoder leaves (B, ...) — batch over dp at axis 0
      state : recurrent state (L, B, feat...) — batch over dp, feature
              (last) dim over 'model'

    Every role degrades to replication when a dim is not divisible."""
    nd = leaf.ndim
    sp = [None] * nd
    if role == "kv":  # (L, B, S, KV, hd)
        from repro.perf_knobs import KNOBS

        if _fits(leaf.shape[1], mesh, dp):
            sp[1] = dp
        if (not KNOBS.decode_seq_shard) and _fits(leaf.shape[3], mesh, "model"):
            sp[3] = "model"
        elif _fits(leaf.shape[2], mesh, "model"):
            sp[2] = "model"
    elif role == "page":  # (L, n_pages, page[, KV, hd])
        from repro.perf_knobs import KNOBS

        if KNOBS.paged_attn_sharded:
            # kernel-compatible layout: the paged-attention kernel is a
            # single-device program, so the shared pools replicate (every
            # device walks the full block table) while slot leaves keep
            # their dp sharding — an opt-in trade of pool memory for
            # gather-free decode under the mesh
            return P(*sp)
        if nd >= 2 and _fits(leaf.shape[1], mesh, dp):
            sp[1] = dp
        if nd == 5 and _fits(leaf.shape[3], mesh, "model"):
            sp[3] = "model"
    elif role == "slot":  # (L, B[, n_bt]) / stripe kpos (L, B, S)
        if nd >= 2 and _fits(leaf.shape[1], mesh, dp):
            sp[1] = dp
    elif role == "enc":  # enc_out (B, T, D) / enc_len (B,)
        if nd >= 1 and _fits(leaf.shape[0], mesh, dp):
            sp[0] = dp
    else:  # "state": recurrent (L, B, feat...) — batch over dp, last dim tp
        if nd >= 2 and _fits(leaf.shape[1], mesh, dp):
            sp[1] = dp
        if nd >= 3 and _fits(leaf.shape[-1], mesh, "model"):
            sp[-1] = "model"
    return P(*sp)


def _infer_cache_roles(node):
    """Name-based role inference for caches without a cfg (legacy callers).

    Mirrors what the families declare: a paged pool dict is recognised by
    its block table, stripe K/V by name+ndim, encoder leaves by name;
    anything else is recurrent state."""
    from repro.models import paging

    if isinstance(node, dict):
        if paging.is_paged(node):
            return paging.paged_roles(node)
        out = {}
        for k, v in node.items():
            if isinstance(v, (dict, tuple, list)):
                out[k] = _infer_cache_roles(v)
            elif k in ("k", "v") and v.ndim == 5:
                out[k] = "kv"
            elif k in ("pos", "kpos"):
                out[k] = "slot"
            elif k in ("enc_out", "enc_len"):
                out[k] = "enc"
            else:
                out[k] = "state"
        return out
    if isinstance(node, (tuple, list)):
        return type(node)(_infer_cache_roles(v) for v in node)
    return "state"


def cache_specs(cache, mesh: Mesh, cfg=None):
    """Decode-cache sharding, both layouts.

    stripe — batch (request-slot) dim over dp; KV heads over 'model' when
    divisible, else the sequence dim; recurrent states shard their feature
    dim over 'model'.

    paged — the shared page pools shard their PAGE axis over dp (size the
    pool with ``models.paging.shard_geometry`` so the page count, reserved
    pages included, divides the mesh) while block tables / pos / alloc
    keep slot-axis sharding; attention's ``pool[bt]`` gather resolves
    cross-shard pages through XLA SPMD like any other indexed gather.

    Roles come from the family (``zoo.cache_shard_roles``) when ``cfg`` is
    given; otherwise they are inferred from leaf names (legacy layout)."""
    dp = batch_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    if cfg is not None:
        from repro.models import zoo

        roles = zoo.cache_shard_roles(cfg, cache)
    else:
        roles = _infer_cache_roles(cache)
    return jax.tree.map(
        lambda role, leaf: _cache_leaf_spec(role, leaf, mesh, dp),
        roles, cache)


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
