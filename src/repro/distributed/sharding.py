"""Path-based sharding rules (MaxText-style logical axes, resolved with
divisibility checks so every assigned architecture maps onto the fixed
(16, 16) / (2, 16, 16) production meshes without manual per-arch tables).

Axis roles:
  dp    : batch — ('pod', 'data') on the multi-pod mesh, ('data',) otherwise
  fsdp  : parameter sharding over 'data' (ZeRO-3 style; gathered on use by
          XLA SPMD). Pod axis intentionally excluded: across pods we run
          pure DP (params replicated per pod, gradients all-reduced over
          'pod' + 'data'), matching the ICI/DCI bandwidth hierarchy.
  tp    : tensor parallel over 'model'
  heads : tp, but only when the head count divides the axis (GQA models
          with few KV heads fall back to unsharded weights — attention then
          runs data-parallel while the FFN keeps full TP)
  ep    : expert parallel over 'model' when n_experts divides it, else
          experts stay TP inside each expert

Every role silently degrades to replication when the dim is not divisible
by the target axis — the rule engine never produces an invalid spec.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.types import PackedHiNM


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return False
    if isinstance(axis, tuple):
        n = int(np.prod([_axis_size(mesh, a) for a in axis]))
    else:
        n = _axis_size(mesh, axis)
    return dim % n == 0 and dim >= n


def _resolve(role, dim: int, mesh: Mesh, cfg) -> Any:
    """role -> mesh axis name (or None), honouring divisibility."""
    if role is None:
        return None
    if role == "dp":
        ax = batch_axes(mesh)
        ax = ax if len(ax) > 1 else ax[0]
        return ax if _fits(dim, mesh, ax) else None
    if role == "fsdp":
        if (cfg is not None and getattr(cfg, "fsdp_pods", False)
                and "pod" in mesh.axis_names and _fits(dim, mesh, ("data", "pod"))):
            return ("data", "pod")
        return "data" if _fits(dim, mesh, "data") else None
    if role == "tp":
        return "model" if _fits(dim, mesh, "model") else None
    if role == "heads":
        return "model" if _fits(dim, mesh, "model") else None
    if role == "ep":
        return "model" if _fits(dim, mesh, "model") else None
    raise ValueError(role)


def _packed_spec(shape: tuple[int, ...], mesh: Mesh, cfg, field: str):
    """Specs for PackedHiNM array fields (layer-stacking dim already
    stripped by the caller). Tile dim T gets TP — tiles are independent
    (DESIGN.md §2). An expert-leading dim takes EP instead when it divides
    'model'."""
    ndim = len(shape)
    if field in ("vals", "nm_idx"):
        t_axis = ndim - 3
    else:  # vec_idx
        t_axis = ndim - 2
    spec = [None] * ndim
    if t_axis > 0 and _fits(shape[0], mesh, "model"):  # expert dim EP
        spec[0] = "model"
        if _fits(shape[-1], mesh, "data"):
            spec[-1] = "data"
        return P(*spec)
    # tiles are independent: prefer sharding T over BOTH axes (outputs are
    # tile-local, so no weight gather is ever needed); fall back to
    # T x model + trailing-dim x data (FSDP-style, gathered on use)
    from repro.perf_knobs import KNOBS

    if KNOBS.packed_t_axes == "both" and _fits(shape[t_axis], mesh, ("model", "data")):
        spec[t_axis] = ("model", "data")
    elif _fits(shape[t_axis], mesh, "model"):
        spec[t_axis] = "model"
        if KNOBS.packed_t_axes != "model_only" and _fits(shape[-1], mesh, "data"):
            spec[-1] = "data"
    return P(*spec)


def _rule_for(path: str, shape: tuple[int, ...], mesh: Mesh, cfg):
    """Return role tuple for a dense param leaf."""
    nd = len(shape)
    seg = path.split("/")
    key = seg[-1]
    parent = seg[-2] if len(seg) > 1 else ""

    def roles(*rs):
        return tuple(rs)

    if key == "table":
        from repro.perf_knobs import KNOBS

        # feature-sharded: the token gather's output is naturally
        # (batch, 'model')-sharded; vocab-sharding forces SPMD to fully
        # rematerialise the (B*S, D) gather output (§Perf iteration 1)
        if KNOBS.embed_feature_shard:
            return roles(None, "tp")
        return roles("tp", "fsdp")
    if key in ("scale",) or (key == "bias" and nd == 1 and parent.startswith("ln")):
        return (None,) * nd
    if key == "lam":
        return roles("tp")
    if key == "conv":
        return roles(None, "tp")
    if key == "r":
        return (None,) * nd
    if key == "b":
        base = (None,) * (nd - 1)
        # bias shards like its weight's output dim
        if parent in ("wq",):
            return base + ("heads" if cfg and cfg.n_heads % _axis_size(mesh, "model") == 0 else None,)
        if parent in ("wk", "wv"):
            return base + ("heads" if cfg and cfg.n_kv_heads % _axis_size(mesh, "model") == 0 else None,)
        if parent in ("wo", "wd", "wout"):
            return base + (None,)
        return base + ("tp",)
    if key != "w":
        return (None,) * nd

    # weight matrices: stored (n_in, n_out); expert stacks (E, n_in, n_out)
    lead = ()
    if nd == 3:  # expert stack
        if cfg and cfg.n_experts and _fits(shape[0], mesh, "model"):
            return ("ep", "fsdp", None)
        lead = (None,)
    if parent in ("wq",):
        out_role = "heads" if cfg and cfg.n_heads % _axis_size(mesh, "model") == 0 else None
        return lead + ("fsdp", out_role)
    if parent in ("wk", "wv"):
        out_role = "heads" if cfg and cfg.n_kv_heads % _axis_size(mesh, "model") == 0 else None
        return lead + ("fsdp", out_role)
    if parent == "wo":
        in_role = "heads" if cfg and cfg.n_heads % _axis_size(mesh, "model") == 0 else None
        return lead + (in_role, "fsdp")
    if parent in ("wd", "wout"):
        return lead + ("tp", "fsdp")
    if parent == "router":
        return lead + (None, None)
    if parent == "lm_head":
        return lead + ("fsdp", "tp")
    # default projection: shard output dim TP, input dim FSDP
    return lead + ("fsdp", "tp")


def _spec_for_leaf(path: str, leaf, mesh: Mesh, cfg) -> P:
    shape = tuple(leaf.shape)
    roles = _rule_for(path, shape, mesh, cfg)
    resolved = []
    used = set()
    for role, dim in zip(roles, shape):
        ax = _resolve(role, dim, mesh, cfg)
        # an axis may appear at most once in a spec
        if ax is not None and not isinstance(ax, tuple) and ax in used:
            ax = None
        if isinstance(ax, tuple) and any(a in used for a in ax):
            ax = None
        if ax is not None:
            used.update(ax if isinstance(ax, tuple) else (ax,))
        resolved.append(ax)
    # scan-stacked layer leading dim: roles computed for the layer shape
    return P(*resolved)


def param_specs(params, mesh: Mesh, cfg=None):
    """Pytree of PartitionSpec matching `params` (arrays or ShapeDtypeStructs).

    Scan-stacked leading dims (n_layers / pattern stacks) are detected by
    comparing path depth: any leaf under 'blocks'/'stacks'/'enc'/'dec' has a
    leading layer axis that is never sharded.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for pathkeys, leaf in flat:
        path = "/".join(_key_str(k) for k in pathkeys)
        stacked = any(s in path.split("/") for s in ("blocks", "enc", "dec", "stacks"))
        field = path.split("/")[-1]
        if field in ("vals", "vec_idx", "nm_idx"):
            inner_shape = tuple(leaf.shape[1:]) if stacked else tuple(leaf.shape)
            spec = _packed_spec(inner_shape, mesh, cfg, field)
            if stacked:
                spec = P(*((None,) + tuple(spec)))
            specs.append(spec)
            continue
        if stacked:
            # strip the layer axis, rule on the per-layer shape, re-prepend
            inner = jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
            spec = _spec_for_leaf(path, inner, mesh, cfg)
            spec = P(*((None,) + tuple(spec)))
        else:
            spec = _spec_for_leaf(path, leaf, mesh, cfg)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "name"):  # GetAttrKey (registered dataclass fields)
        return str(k.name)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k).lstrip(".")


def opt_state_specs(opt_state, pspecs):
    """Specs for optimizer state given param specs.

    AdamW: mu/nu mirror params. Adafactor: vr drops the last dim's axis,
    vc drops the second-to-last. count: replicated."""
    if "mu" in opt_state:
        return {
            "mu": pspecs,
            "nu": pspecs,
            "count": P(),
        }

    def fact(spec_leaf, state_leaf):
        spec = tuple(spec_leaf)
        if isinstance(state_leaf, dict) and "vr" in state_leaf:
            return {
                "vr": P(*spec[:-1]) if len(spec) > 1 else P(),
                "vc": P(*(spec[:-2] + spec[-1:])) if len(spec) > 1 else P(),
            }
        return {"v": P(*spec)}

    v = jax.tree.map(
        fact, pspecs, opt_state["v"],
        is_leaf=lambda x: isinstance(x, P),
    )
    return {"v": v, "count": P()}


def batch_specs(batch, mesh: Mesh):
    """Input batch: shard the leading (global batch) dim over dp axes."""
    dp = batch_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    def f(leaf):
        if leaf.ndim == 0:
            return P()
        if _fits(leaf.shape[0], mesh, dp):
            return P(dp, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(f, batch)


def cache_specs(cache, mesh: Mesh, cfg=None):
    """Decode-cache sharding: batch over dp; KV heads over 'model' when
    divisible, else the sequence (slot) dim over 'model'; recurrent states
    shard their feature dim over 'model'."""
    dp = batch_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = []
    for pathkeys, leaf in flat:
        path = "/".join(_key_str(k) for k in pathkeys)
        name = path.split("/")[-1]
        nd = leaf.ndim
        if name in ("k", "v") and nd == 5:  # (L, B, S, KV, hd)
            from repro.perf_knobs import KNOBS

            sp = [None] * 5
            if _fits(leaf.shape[1], mesh, dp):
                sp[1] = dp
            if (not KNOBS.decode_seq_shard) and _fits(leaf.shape[3], mesh, "model"):
                sp[3] = "model"
            elif _fits(leaf.shape[2], mesh, "model"):
                sp[2] = "model"
            specs.append(P(*sp))
        elif name in ("pos", "kpos"):
            # per-slot position tracking: (L, B) / (L, B, S) — follow the
            # k/v batch sharding so slot writes stay local to the dp shard
            sp = [None] * nd
            if nd >= 2 and _fits(leaf.shape[1], mesh, dp):
                sp[1] = dp
            specs.append(P(*sp))
        elif name == "enc_len" and nd == 1:  # (B,) — follow enc_out's batch
            specs.append(P(dp if _fits(leaf.shape[0], mesh, dp) else None))
        elif name == "enc_out" and nd == 3:  # (B, T, D)
            sp = [None] * 3
            if _fits(leaf.shape[0], mesh, dp):
                sp[0] = dp
            specs.append(P(*sp))
        else:
            # recurrent states: (L, B, feat...) — batch over dp, last dim tp
            sp = [None] * nd
            if nd >= 2 and _fits(leaf.shape[1], mesh, dp):
                sp[1] = dp
            if nd >= 3 and _fits(leaf.shape[-1], mesh, "model"):
                sp[-1] = "model"
            specs.append(P(*sp))
    return jax.tree_util.tree_unflatten(treedef, specs)


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
