from repro.distributed.sharding import (
    batch_axes,
    batch_specs,
    cache_specs,
    opt_state_specs,
    param_specs,
)

__all__ = [
    "batch_axes",
    "batch_specs",
    "cache_specs",
    "opt_state_specs",
    "param_specs",
]
