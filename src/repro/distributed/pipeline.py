"""Pipeline parallelism over the 'pod' axis (DESIGN.md §3, optional).

A GPipe-style microbatch pipeline built on shard_map + ppermute: layer
stages are sharded over the pipeline axis and microbatches stream through
a single pipe register. Per step the schedule runs
(n_micro + n_stages - 1) ticks; at tick t stage s applies its layers to
microbatch (t - s), then the register rotates one stage forward. The last
stage banks finished microbatches; a psum replicates the banked output.

The production dry-run keeps pod=DP (the realistic choice at 2 pods); this
executor exists for deeper pods / DCN-bound regimes and is exercised at
toy scale by tests/test_pipeline.py. Forward-only (serving/eval); training
needs the 1F1B reverse schedule — noted as future work.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def pipeline_apply(
    stage_fn: Callable,        # (stage_params, x_mb) -> x_mb
    stage_params,              # pytree stacked over a leading stage axis
    x: jax.Array,              # (n_micro, mb, ...) microbatched input
    mesh,
    axis: str = "pod",
) -> jax.Array:
    """Stream microbatches through all pipeline stages. Returns outputs in
    microbatch order, replicated over `axis`."""
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]

    def body(params_local, x_all):
        params_local = jax.tree.map(lambda a: a[0], params_local)
        s = jax.lax.axis_index(axis)
        ticks = n_micro + n_stages - 1
        reg = jnp.zeros_like(x_all[0])
        outbuf = jnp.zeros_like(x_all)

        def tick(t, carry):
            reg, outbuf = carry
            mb_id = t - s
            active = (mb_id >= 0) & (mb_id < n_micro)
            feed = x_all[jnp.clip(t, 0, n_micro - 1)]
            inp = jnp.where(s == 0, feed, reg)
            out = stage_fn(params_local, inp)
            out = jnp.where(active, out, reg)
            # last stage banks the microbatch it just finished
            fin_id = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            bank = jnp.where((s == n_stages - 1) & active, out, 0.0)
            outbuf = outbuf.at[fin_id].add(bank)
            reg = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return reg, outbuf

        _, outbuf = jax.lax.fori_loop(0, ticks, tick, (reg, outbuf))
        # only the last stage banked anything; psum replicates the result
        return jax.lax.psum(outbuf, axis)

    return compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )(stage_params, x)
