from repro.optim.optimizers import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
)

__all__ = [
    "adafactor_init",
    "adafactor_update",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "cosine_schedule",
    "make_optimizer",
]
