"""Optimizers (functional, pytree-based — no external deps).

- AdamW with fp32 state and decoupled weight decay (default).
- Adafactor (factored second moment, no first moment) for the very large
  configs (grok-1) where AdamW state would not fit the per-device HBM
  budget at 256 chips (DESIGN.md §3).
- Global-norm clipping and cosine/linear-warmup schedules.

Masked params (HiNM): the train step re-applies masks after the update, so
optimizers stay mask-agnostic (pruned coordinates are re-zeroed at use time).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads, state, params, lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1
):
    count = state["count"] + 1
    c = count.astype(jnp.float32)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_p = jax.tree.leaves(params)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])

    new_mu, new_nu, new_p = [], [], []
    for g, mu, nu, p in zip(flat_g, flat_mu, flat_nu, flat_p):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * g32 * g32
        mu_hat = mu / (1 - b1**c)
        nu_hat = nu / (1 - b2**c)
        step = mu_hat / (jnp.sqrt(nu_hat) + eps) + weight_decay * p.astype(jnp.float32)
        new_mu.append(mu)
        new_nu.append(nu)
        new_p.append((p.astype(jnp.float32) - lr * step).astype(p.dtype))
    return (
        jax.tree.unflatten(tdef, new_p),
        {
            "mu": jax.tree.unflatten(tdef, new_mu),
            "nu": jax.tree.unflatten(tdef, new_nu),
            "count": count,
        },
    )


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, 2018) — factored second moments
# ---------------------------------------------------------------------------


def adafactor_init(params):
    def f(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(f, params), "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, lr, decay=0.8, eps=1e-30, clip_thr=1.0):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    beta = 1.0 - c ** (-decay)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_p = jax.tree.leaves(params)
    flat_v = tdef.flatten_up_to(state["v"])

    new_v, new_p = [], []
    for g, v, p in zip(flat_g, flat_v, flat_p):
        g32 = g.astype(jnp.float32)
        sq = g32 * g32 + eps
        if p.ndim >= 2:
            vr = beta * v["vr"] + (1 - beta) * sq.mean(axis=-1)
            vc = beta * v["vc"] + (1 - beta) * sq.mean(axis=-2)
            denom = vr.mean(axis=-1, keepdims=True)
            prec = (vr / jnp.maximum(denom, eps))[..., None] * vc[..., None, :]
            update = g32 * jax.lax.rsqrt(jnp.maximum(prec, eps))
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": beta * v["v"] + (1 - beta) * sq}
            update = g32 * jax.lax.rsqrt(jnp.maximum(nv["v"], eps))
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-12)
        update = update / jnp.maximum(1.0, rms / clip_thr)
        new_v.append(nv)
        new_p.append((p.astype(jnp.float32) - lr * update).astype(p.dtype))
    return (
        jax.tree.unflatten(tdef, new_p),
        {"v": jax.tree.unflatten(tdef, new_v), "count": count},
    )


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (params, state)
    name: str


def make_optimizer(name: str) -> Optimizer:
    if name == "adamw":
        return Optimizer(adamw_init, adamw_update, "adamw")
    if name == "adafactor":
        return Optimizer(adafactor_init, adafactor_update, "adafactor")
    raise ValueError(f"unknown optimizer {name!r}")
