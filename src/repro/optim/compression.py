"""Error-feedback top-k gradient compression for the DP all-reduce.

At 1000+-node scale the data-parallel gradient all-reduce can dominate step
time for small models / large DP degrees. We provide the standard
EF-SGD/EF21-style compressor: each step, only the top-k fraction of gradient
magnitudes (per leaf) is exchanged; the residual is carried in a local error
buffer and added back before the next compression. Convergence-neutral at
k >= ~1% in practice.

The compressor runs *inside* the jit'd train step (the masked gradient is
still all-reduced by XLA, but with (1-k) of entries zeroed, enabling
sparse-friendly collective implementations; on TPU the win is realised via
reduced-precision/structured all-reduce — we expose the hook and benchmark
the bytes delta in benchmarks/compression_bench.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_topk_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_topk_compress(grads, error, k_frac: float = 0.01):
    """Returns (compressed_grads, new_error). Top-k by |g| per leaf."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        flat = g32.reshape(-1)
        k = max(1, int(flat.shape[0] * k_frac))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(g32) >= thresh
        sent = jnp.where(mask, g32, 0.0)
        return sent.astype(g.dtype), g32 - sent

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(tdef, [o[0] for o in outs]),
        jax.tree.unflatten(tdef, [o[1] for o in outs]),
    )
