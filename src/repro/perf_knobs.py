"""Performance-iteration knobs (EXPERIMENTS.md §Perf).

Each knob selects between the paper-faithful/baseline realisation and a
beyond-paper optimisation candidate. The roofline harness and perf scripts
flip these per run so every hypothesis -> change -> measure cycle is a
one-line diff; production defaults are set after the hillclimb.
"""
from __future__ import annotations

import contextlib
import dataclasses


@dataclasses.dataclass
class Knobs:
    # Embedding table sharding: False = vocab-sharded ('model','data') —
    # forces an involuntary resharding of the (B*S, D) gather output;
    # True = feature-sharded (None,'model') — gather output lands directly
    # in (dp, None, 'model') layout. (baseline: False; flipped by §Perf)
    embed_feature_shard: bool = False
    # Packed HiNM tile sharding for serving: "both" = T over
    # ('model','data') (max param spread, activation gathers);
    # "model" = T over 'model' + trailing dim FSDP over 'data';
    # "model_only" = T over 'model', trailing dims replicated (required by
    # the shard_map fast path — the local contraction needs full K).
    packed_t_axes: str = "model_only"
    # Explicit shard_map packed matmul (tile-local, zero-collective).
    packed_shard_map: bool = True
    # Decode attention: sequence-shard the KV cache over 'model' even when
    # KV heads divide it (S-sharding scales to any head count).
    decode_seq_shard: bool = True
    # Sequence-parallel decode attention (shard_map): each model shard
    # attends over its local cache slice; only O(B*H*hd) softmax stats are
    # psum'd — replaces the per-layer full-cache all-gather.
    seq_parallel_decode: bool = True
    # Paged-attention decode kernel (kernels/paged_attn): "auto" = Pallas
    # on TPU, jnp pool[bt] gather elsewhere; "interpret" = the kernel under
    # the Pallas interpreter (CPU CI correctness mode); "pallas"/"on" =
    # force the compiled kernel; "off" = always the gather path.
    paged_attn: str = "auto"
    # Run the paged-attention kernel under a >1-shard mesh by replicating
    # the page pools (distributed/sharding "page" role). Off by default:
    # the kernel is a single-device program, so a page-sharded pool makes
    # the Scheduler fall back to the SPMD gather path instead.
    paged_attn_sharded: bool = False
    # Serving telemetry (serve/telemetry): False = trace-time instruments
    # only (compile counts, kernel dispatch decisions — free per step);
    # True = schedulers default to full wall-clock instrumentation +
    # request-lifecycle tracing (<3% decode tok/s at bench shapes,
    # CI-asserted). Per-scheduler override: Scheduler(telemetry=...).
    telemetry: bool = False
    # Cross-entropy chunk length (sequence positions per logits chunk).
    xent_chunk: int = 512
    # Attention block sizes (train/prefill flash-style scan).
    kv_block: int = 512
    q_block: int = 512
    # Causal block skipping (static per-q-chunk KV prefixes). Measured
    # flop-neutral on cost probes (per-chunk checkpoint recompute offsets
    # the halving) and +20 GB artifact memory on granite train -> refuted
    # as a default; kept opt-in (§Perf iteration log).
    causal_block_skip: bool = False


KNOBS = Knobs()


@contextlib.contextmanager
def knobs(**overrides):
    global KNOBS
    prev = KNOBS
    KNOBS = dataclasses.replace(KNOBS, **overrides)
    try:
        yield KNOBS
    finally:
        KNOBS = prev
