"""DeiT-base — the paper's second-order one-shot target (Table 1).
Patch-embedding frontend stub (196 tokens @ 224px/16), transformer encoder
dims; benchmarks use its Linear shapes with synthetic Fisher saliency."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deit_base",
    family="vlm",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=1000,          # classifier head as vocab
    head_dim=64,
    qkv_bias=True,
    act="gelu",
    norm="layernorm",
    frontend="patch",
    frontend_tokens=196,
)
