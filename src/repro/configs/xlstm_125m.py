"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304; alternating
sLSTM + mLSTM blocks (blocks carry their own projections, no separate FFN).
Recurrent state is O(1) in sequence length -> runs long_500k.
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm_125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    eos_id=0,  # <|endoftext|> (gpt-neox style)
    head_dim=192,
    block_pattern=("mlstm", "slstm"),
    norm="layernorm",
)
