"""Architecture config schema shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax.numpy as jnp

from repro.core.types import HiNMConfig

ARCH_IDS = (
    "qwen2_5_14b",
    "starcoder2_15b",
    "qwen2_0_5b",
    "codeqwen1_5_7b",
    "recurrentgemma_9b",
    "xlstm_125m",
    "phi_3_vision_4_2b",
    "seamless_m4t_medium",
    "grok_1_314b",
    "granite_moe_3b_a800m",
)

SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "swiglu"          # swiglu | gelu
    tie_embeddings: bool = False
    # tokenizer end-of-sequence id; -1 = none (generation runs to
    # max_new_tokens). Serving ignores ids outside [0, vocab) — e.g. the
    # full-tokenizer id on a vocab-reduced smoke config.
    eos_id: int = -1
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    # --- hybrid (recurrentgemma) / ssm (xlstm) ---
    block_pattern: tuple[str, ...] = ()  # period of block kinds per layer
    window: int = 0                       # local-attention window (0 = full)
    rglru_dim: int = 0
    # --- enc-dec ---
    n_enc_layers: int = 0
    # --- speculative decoding (serve/spec) ---
    # arch id of the paired small draft model (same tokenizer family); ""
    # = none. `serve.spec.ModelDrafter.from_zoo` resolves it via load_arch.
    draft_arch: str = ""
    # --- modality frontend stub ---
    frontend: str = ""           # "" | "patch" | "frames"
    frontend_tokens: int = 0     # stub tokens prepended (vlm) / encoder len ratio
    # --- numerics / sparsity ---
    dtype: Any = jnp.bfloat16
    hinm: HiNMConfig = HiNMConfig()
    max_seq: int = 32768
    optimizer: str = "adamw"     # adafactor for the largest configs
    fsdp_pods: bool = False      # extend FSDP param sharding across pods
                                 # (DCN gather amortised by grad accumulation;
                                 # needed only for the 314B config)
    # which shape cells apply ("" entries are skipped with a reason)
    skip_shapes: tuple[str, ...] = ()

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so TP-16 sharding divides evenly."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def attn_out_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_out_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token decode? (recurrent / windowed only)"""
        return self.family in ("hybrid", "ssm")

    def reduced(self, **overrides) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        base = dict(
            n_layers=min(self.n_layers, 2 * max(1, len(self.block_pattern))),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            head_dim=32,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_enc_layers=min(self.n_enc_layers, 2),
            window=min(self.window, 64) if self.window else 0,
            rglru_dim=128 if self.rglru_dim else 0,
            max_seq=256,
            dtype=jnp.float32,
            hinm=HiNMConfig(v=8, n=2, m=4, vector_sparsity=0.5),
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)


# the paper's own experimental models (benchmarks/examples; not part of
# the assigned dry-run matrix)
PAPER_IDS = ("bert_base", "deit_base")


def load_arch(name: str) -> ArchConfig:
    """Load `src/repro/configs/<name>.py` and return its CONFIG."""
    if name not in ARCH_IDS + PAPER_IDS:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_IDS + PAPER_IDS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG
