"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; RG-LRU + local attention in a 1:2 pattern (rec, rec, attn)
with window 2048. Sub-quadratic -> runs the long_500k decode cell.
[arXiv:2402.19427; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma_9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    eos_id=1,  # <eos> (gemma sentencepiece)
    head_dim=256,
    block_pattern=("rec", "rec", "attn"),
    window=2048,
    rglru_dim=4096,
    act="gelu",
    norm="rmsnorm",
)
