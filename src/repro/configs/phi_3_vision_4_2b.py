"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064; phi3-mini backbone + CLIP frontend STUB (input_specs provides
576 precomputed patch embeddings). [hf:microsoft/Phi-3-vision-128k-instruct]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi_3_vision_4_2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    eos_id=2,  # </s> (llama sentencepiece)
    head_dim=96,
    frontend="patch",
    frontend_tokens=576,
    act="swiglu",
    norm="rmsnorm",
)
