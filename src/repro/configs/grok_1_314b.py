"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2. Adafactor optimizer (AdamW state would
exceed the 256-chip HBM budget, DESIGN.md §3). [hf:xai-org/grok-1]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok_1_314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    eos_id=2,  # <|eos|>
    head_dim=128,
    n_experts=8,
    top_k=2,
    act="swiglu",
    norm="rmsnorm",
    optimizer="adafactor",
    fsdp_pods=True,
)
