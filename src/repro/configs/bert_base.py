"""BERT-base — the paper's gradual-pruning target (Table 2). Used by the
reproduction benchmarks for exact weight shapes; runnable as a causal-LM
variant of the same dims for end-to-end sanity (the HiNM/gyro machinery is
orientation-agnostic)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="bert_base",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=30522,
    head_dim=64,
    qkv_bias=True,
    act="gelu",
    norm="layernorm",
)
