"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152; GQA + RoPE, LayerNorm + GeLU MLP. [arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2_15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    eos_id=0,  # <|endoftext|>
    head_dim=128,
    qkv_bias=True,
    rope_theta=100_000.0,
    act="gelu",
    norm="layernorm",
)
