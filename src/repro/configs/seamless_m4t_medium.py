"""seamless-m4t-medium [audio] — 12L enc + 12L dec, d_model=1024 16H
(MHA kv=16) d_ff=4096 vocab=256206; encoder-decoder, speech frontend STUB
(input_specs provides precomputed frame embeddings; decoder length =
seq_len / 4, DESIGN.md §6). [arXiv:2308.11596; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless_m4t_medium",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    eos_id=3,  # </s> (nllb fairseq)
    head_dim=64,
    frontend="frames",
    act="gelu",
    norm="layernorm",
)
