from repro.configs.base import ARCH_IDS, SHAPES, ArchConfig, load_arch

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "load_arch"]
