"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
per expert, vocab=49155, MoE 40 experts top-8. The assignment line also
mentions "32 experts" in the trailing note; we follow the explicit
"MoE 40e top-8" field (noted in DESIGN.md §9).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite_moe_3b_a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    eos_id=0,  # <|end_of_text|>
    head_dim=64,
    n_experts=40,
    top_k=8,
    act="swiglu",
    norm="rmsnorm",
)
