"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936; GQA + QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_0_5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151936,
    eos_id=151643,  # <|endoftext|>
    head_dim=64,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
)
