"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064; GQA + QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_5_14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab=152064,
    eos_id=151643,  # <|endoftext|>
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    act="swiglu",
    norm="rmsnorm",
    # speculative decoding pair: the 0.5B shares the Qwen2 tokenizer (its
    # 151936-entry vocab is a prefix of the 14B's padded 152064 table)
    draft_arch="qwen2_0_5b",
)
