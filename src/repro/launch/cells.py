"""Dry-run cell construction: (arch config, shape, mesh) -> lowered step.

A "cell" is one (architecture x input-shape) point of the assignment
matrix. Kinds:
  train    -> masked-dense HiNM train step (params + opt state + masks)
  prefill  -> serving prefill over packed HiNM weights (fills the cache)
  decode   -> serving decode step over packed HiNM weights (one token)

Everything is abstract (ShapeDtypeStruct): no arrays are allocated.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import SHAPES, ArchConfig
from repro.data.pipeline import make_batch_specs
from repro.distributed import sharding as shd
from repro.models import zoo
from repro.optim import make_optimizer
from repro.train import abstract as abst
from repro.train import steps as tsteps


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    jitted: Any
    args: tuple
    skipped: str = ""


def shape_applicable(cfg: ArchConfig, shape_name: str) -> str:
    """'' if the cell runs; otherwise the documented skip reason."""
    seq, batch, kind = SHAPES[shape_name]
    if shape_name in cfg.skip_shapes:
        return "excluded by config"
    if shape_name == "long_500k" and not cfg.sub_quadratic():
        return "full quadratic attention at 524k seq is out of scope (DESIGN.md §6)"
    return ""


def _named(tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if s is not None else None,
        tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def pick_microbatches(cfg: ArchConfig, seq: int, batch: int, mesh,
                      budget_bytes: float = 4e9) -> int:
    """Grad-accumulation factor so the remat'd layer-input activation stack
    (L x B_loc x S x D x 2B) stays under ~4 GB/device. M must divide the
    per-device batch so every microbatch still shards evenly."""
    from repro.models import probe_mode

    if probe_mode.enabled():
        return 1  # cost probes: no accumulation loop
    dp = 1
    for a in shd.batch_axes(mesh):
        dp *= mesh.shape[a]
    b_loc = max(1, batch // dp)
    stack = cfg.n_layers * b_loc * seq * cfg.d_model * 2
    m = 1
    while stack / m > budget_bytes and m < b_loc and b_loc % (m * 2) == 0:
        m *= 2
    return m


def build_train_cell(cfg: ArchConfig, shape_name: str, mesh,
                     shape_override: tuple[int, int] | None = None) -> Cell:
    seq, batch, _ = SHAPES[shape_name]
    if shape_override:
        seq, batch = shape_override
    params_shape = jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0), cfg))
    opt = make_optimizer(cfg.optimizer)
    opt_shape = jax.eval_shape(opt.init, params_shape)
    masks_shape = abst.abstract_masks(params_shape, cfg)
    batch_shape = make_batch_specs(
        seq, batch, cfg.vocab, cfg.frontend, cfg.d_model, cfg.frontend_tokens
    )
    mb = pick_microbatches(cfg, seq, batch, mesh)
    step_fn, _ = tsteps.make_train_step(
        cfg, mesh, optimizer_name=cfg.optimizer, microbatches=mb
    )
    jitted, _, _ = tsteps.shard_train_step(
        step_fn, cfg, mesh, params_shape, opt_shape, masks_shape, batch_shape
    )
    args = (params_shape, opt_shape, masks_shape, batch_shape,
            jax.ShapeDtypeStruct((), jnp.int32), None)
    return Cell(cfg.name, shape_name, "train", jitted, args)


def _serve_shapes(cfg: ArchConfig, shape_name: str,
                  shape_override: tuple[int, int] | None = None):
    seq, batch, kind = SHAPES[shape_name]
    if shape_override:
        seq, batch = shape_override
    params_shape = jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0), cfg))
    packed_shape = abst.abstract_packed(params_shape, cfg)
    kw = {}
    if cfg.family == "encdec":
        kw["t_enc"] = seq
        cache_seq = max(seq // 4, 8)
    else:
        cache_seq = seq
    if cfg.family in ("hybrid",):
        cache_seq = seq  # window-bounded internally
    cache_shape = jax.eval_shape(
        lambda: zoo.make_cache(cfg, batch, cache_seq, **kw)
    )
    return params_shape, packed_shape, cache_shape, seq, batch


def build_decode_cell(cfg: ArchConfig, shape_name: str, mesh,
                      shape_override: tuple[int, int] | None = None) -> Cell:
    packed = _serve_shapes(cfg, shape_name, shape_override)
    _, packed_shape, cache_shape, seq, batch = packed

    def decode_fn(params, tokens, cache):
        return zoo.decode_step(params, cfg, tokens, cache)

    jitted, _, _ = tsteps.shard_serve_step(
        decode_fn, cfg, mesh, packed_shape, cache_shape, batch
    )
    tokens = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    return Cell(cfg.name, shape_name, "decode", jitted,
                (packed_shape, tokens, cache_shape))


def build_prefill_cell(cfg: ArchConfig, shape_name: str, mesh,
                       shape_override: tuple[int, int] | None = None) -> Cell:
    _, packed_shape, cache_shape, seq, batch = _serve_shapes(
        cfg, shape_name, shape_override)
    pspecs = shd.param_specs(packed_shape, mesh, cfg)
    cspecs = shd.cache_specs(cache_shape, mesh, cfg)

    if cfg.family == "encdec":
        tok_len = seq // 4
        embeds = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16)
    elif cfg.frontend == "patch":
        tok_len = seq - cfg.frontend_tokens
        embeds = jax.ShapeDtypeStruct((batch, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    else:
        tok_len = seq
        embeds = None
    tokens = jax.ShapeDtypeStruct((batch, tok_len), jnp.int32)

    def prefill_fn(params, tokens, cache, embeds=None):
        last, new_cache = zoo.prefill(params, cfg, tokens, cache, embeds=embeds)
        return zoo.logits_fn(params, cfg, last), new_cache

    bspec = shd.batch_specs({"t": tokens}, mesh)["t"]
    in_shardings = [_named(pspecs, mesh), _named(bspec, mesh), _named(cspecs, mesh)]
    args = [packed_shape, tokens, cache_shape]
    if embeds is not None:
        espec = shd.batch_specs({"e": embeds}, mesh)["e"]
        in_shardings.append(_named(espec, mesh))
        args.append(embeds)
    logits_spec = P(tuple(bspec)[0], "model")
    jitted = jax.jit(
        prefill_fn,
        in_shardings=tuple(in_shardings),
        out_shardings=(_named(logits_spec, mesh), _named(cspecs, mesh)),
        donate_argnums=(2,),
    )
    return Cell(cfg.name, shape_name, "prefill", jitted, tuple(args))


def build_cell(cfg: ArchConfig, shape_name: str, mesh,
               shape_override: tuple[int, int] | None = None) -> Cell:
    skip = shape_applicable(cfg, shape_name)
    if skip:
        return Cell(cfg.name, shape_name, SHAPES[shape_name][2], None, (), skipped=skip)
    kind = SHAPES[shape_name][2]
    with compat.set_mesh(mesh):
        if kind == "train":
            return build_train_cell(cfg, shape_name, mesh, shape_override)
        if kind == "prefill":
            return build_prefill_cell(cfg, shape_name, mesh, shape_override)
        return build_decode_cell(cfg, shape_name, mesh, shape_override)


def lower_cell(cell: Cell, mesh):
    with compat.set_mesh(mesh):
        return cell.jitted.lower(*cell.args)
