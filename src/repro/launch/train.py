"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --scale tiny \
      --steps 200 --batch 8 --seq 256 --gradual

Local runs use a host mesh over the available devices; `--production`
lowers against the 16x16 production mesh instead (dry-run semantics).
HiNM gradual pruning is on by default past --nm-step; `--method noperm`
ablates the permutation.
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np


def main() -> None:
    from repro.configs.base import load_arch
    from repro.core.types import HiNMConfig
    from repro.data import SyntheticLMData
    from repro.launch.mesh import make_host_mesh
    from repro.models import zoo
    from repro.optim import cosine_schedule, make_optimizer
    from repro.train import gradual, loop, steps as tsteps
    from repro.train.abstract import abstract_masks

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--gradual", action="store_true")
    ap.add_argument("--method", default="gyro",
                    choices=["gyro", "noperm", "v1", "v2", "icp_only", "ocp_only"])
    ap.add_argument("--nm-step", type=int, default=-1)
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = load_arch(args.arch)
    if args.scale == "tiny":
        cfg = cfg.reduced(max_seq=args.seq)
    mesh = make_host_mesh()

    key = jax.random.PRNGKey(args.seed)
    params = zoo.init(key, cfg)
    opt = make_optimizer(cfg.optimizer)
    opt_state = opt.init(params)
    masks = jax.tree.map(lambda x: None, params)  # dense until the schedule fires

    data = SyntheticLMData(cfg.vocab, args.seq, args.batch, seed=args.seed)
    lr_fn = cosine_schedule(args.lr, warmup=20, total=args.steps)
    step_fn, _ = tsteps.make_train_step(cfg, mesh, optimizer_name=cfg.optimizer,
                                        lr_fn=lr_fn)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    def batch_iter():
        for b in data.iterator():
            yield {k: jnp.asarray(v) for k, v in b.items()}

    mask_schedule = None
    if args.gradual:
        nm_step = args.nm_step if args.nm_step > 0 else args.steps // 2
        sched = gradual.GradualSchedule(
            target=cfg.hinm,
            vector_end_step=nm_step * 2 // 3,
            nm_step=nm_step,
        )
        mask_schedule = gradual.make_mask_schedule(cfg, sched, method=args.method)

    state = loop.LoopState(params=params, opt_state=opt_state, masks=masks)
    lcfg = loop.LoopConfig(
        total_steps=args.steps,
        checkpoint_every=max(args.steps // 4, 25),
        checkpoint_dir=args.checkpoint_dir,
    )
    with compat.set_mesh(mesh):
        final = loop.run(state, jitted, batch_iter(), lcfg)
    print(f"done at step {final.step}")


if __name__ == "__main__":
    main()
