"""Extract roofline inputs from compiled dry-run artifacts.

- FLOPs / bytes from compiled.cost_analysis()  (caveat: XLA counts a while
  loop body ONCE; the roofline harness corrects via layer-unrolled cost
  probes — see benchmarks/roofline.py).
- Collective bytes by parsing the compiled HLO text: sum of operand sizes
  of all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute ops, with while-loop trip-count attribution handled by
  the caller.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes per collective kind over the module text.

    Output shape is used (for all-gather it is the post-gather size = bytes
    received per device; for all-reduce it equals the tensor size, the
    standard 2(n-1)/n factor is applied by the roofline model, not here).
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        op = m.group(2)
        base = op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            out[base] += shape_bytes(m.group(1))
            counts[base] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def collective_bytes_nested(hlo_text: str, loop_trips: int) -> dict:
    """Collective bytes with while-body scaling.

    HLO text lists one computation per block; collectives inside non-ENTRY
    computations sit in some loop body (layer scan, microbatch loop, ...)
    and are scaled by `loop_trips` (the dominant layer-loop trip count).
    This is exact for the layer scan and an upper bound for collectives in
    shorter loops (xent chunks); ENTRY-level collectives count once.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    in_entry = False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            in_entry = True
            continue
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            in_entry = False
            continue
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        op = m.group(2)
        base = op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            scale = 1.0 if in_entry else float(loop_trips)
            out[base] += shape_bytes(m.group(1)) * scale
    return {"bytes": out, "total_bytes": sum(out.values())}


def cost_summary(compiled) -> dict:
    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    return {
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
