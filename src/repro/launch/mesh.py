"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state. The single-pod mesh is 16x16 = 256 chips
("data", "model"); the multi-pod mesh prepends a "pod" axis (2 pods = 512
chips). Data parallelism runs over ("pod", "data") — the pod axis carries
only the gradient all-reduce (DCN-friendly), while FSDP parameter sharding
stays inside a pod on "data" (ICI).
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = max(1, n // model)
    return compat.make_mesh((data, model), ("data", "model"))
