import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory / cost / collective stats.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_0_5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single   # 16x16 only

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and a
summary table is printed (consumed by EXPERIMENTS.md §Dry-run and the
roofline harness).
"""

import argparse
import json
import time
import traceback


def main() -> None:
    import jax  # deferred: device count is locked at first jax import

    from repro.configs.base import ARCH_IDS, SHAPES, load_arch
    from repro.launch import cells as cell_lib
    from repro.launch import hlo_stats
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--collectives", action="store_true",
                    help="also parse per-kind collective bytes from the HLO")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    os.makedirs(args.out, exist_ok=True)
    rows = []
    failures = 0
    for arch in archs:
        cfg = load_arch(arch)
        for shape in shapes:
            for mesh_name, mesh in meshes:
                tag = f"{arch}__{shape}__{mesh_name}"
                t0 = time.time()
                try:
                    cell = cell_lib.build_cell(cfg, shape, mesh)
                    if cell.skipped:
                        rows.append((tag, "SKIP", cell.skipped))
                        with open(os.path.join(args.out, tag + ".json"), "w") as f:
                            json.dump({"status": "skipped", "reason": cell.skipped,
                                       "arch": arch, "shape": shape,
                                       "mesh": mesh_name}, f, indent=1)
                        print(f"[SKIP] {tag}: {cell.skipped}", flush=True)
                        continue
                    lowered = cell_lib.lower_cell(cell, mesh)
                    compiled = lowered.compile()
                    stats = hlo_stats.cost_summary(compiled)
                    if args.collectives:
                        stats["collectives"] = hlo_stats.collective_bytes(
                            compiled.as_text()
                        )
                    stats.update(
                        status="ok", arch=arch, shape=shape, mesh=mesh_name,
                        kind=cell.kind, devices=int(mesh.devices.size),
                        compile_seconds=round(time.time() - t0, 1),
                    )
                    with open(os.path.join(args.out, tag + ".json"), "w") as f:
                        json.dump(stats, f, indent=1)
                    hbm = (stats["argument_bytes"] + stats["temp_bytes"]
                           + stats["output_bytes"] - stats["alias_bytes"]) / 1e9
                    rows.append((tag, "OK",
                                 f"hbm={hbm:.2f}GB flops/dev={stats['flops_per_device']/1e12:.2f}T "
                                 f"({stats['compile_seconds']}s)"))
                    print(f"[OK]   {tag}: {rows[-1][2]}", flush=True)
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures += 1
                    rows.append((tag, "FAIL", repr(e)))
                    with open(os.path.join(args.out, tag + ".json"), "w") as f:
                        json.dump({"status": "failed", "error": traceback.format_exc(),
                                   "arch": arch, "shape": shape, "mesh": mesh_name},
                                  f, indent=1)
                    print(f"[FAIL] {tag}: {e!r}", flush=True)

    print("\n=== dry-run summary ===")
    ok = sum(1 for _, s, _ in rows if s == "OK")
    sk = sum(1 for _, s, _ in rows if s == "SKIP")
    print(f"{ok} ok / {sk} skipped / {failures} failed / {len(rows)} cells")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
