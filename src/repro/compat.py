"""Version shims for the pinned jax (0.4.37).

The sharding API moved between 0.4.x and 0.5+: `jax.sharding.AxisType`,
`jax.sharding.get_abstract_mesh`, `jax.set_mesh`, the `axis_types=` kwarg
of `jax.make_mesh`, and the `(shape, names)` AbstractMesh constructor all
post-date the pin. Everything here resolves to the modern API when it
exists and to the legacy equivalent otherwise, so the rest of the codebase
never branches on jax versions.
"""
from __future__ import annotations

import jax

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_GET_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")
_HAS_SET_MESH = hasattr(jax, "set_mesh")


def make_mesh(axis_shapes, axis_names):
    """jax.make_mesh with Auto axis types when the kwarg exists."""
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(axis_shapes, axis_names)


def abstract_mesh(axis_shapes, axis_names):
    """AbstractMesh((16, 16), ("data", "model")) on every supported jax."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:  # 0.4.x: single shape_tuple of (name, size) pairs
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def set_mesh(mesh):
    """Context manager activating `mesh` for sharding-constraint resolution."""
    if _HAS_SET_MESH:
        return jax.set_mesh(mesh)
    # Legacy global mesh context: Mesh is itself a context manager that
    # installs the thread-local resource env with_sharding_constraint reads.
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map with replication checking off, on every supported jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def get_abstract_mesh():
    """The mesh active for sharding constraints, or None outside a context.

    On 0.4.x there is no abstract-mesh tracking; fall back to the physical
    mesh of the legacy resource env, which exposes the same `.empty`,
    `.axis_names`, and `.shape` surface the sharding helpers use.
    """
    if _HAS_GET_ABSTRACT_MESH:
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as mesh_lib

    env = getattr(mesh_lib, "thread_resources", None)
    if env is None:
        return None
    physical = env.env.physical_mesh
    if physical is None or physical.empty:
        return None
    return physical
