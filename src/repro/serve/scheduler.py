"""Continuous-batching scheduler with a device-resident decode loop.

The decode batch is a fixed-width pool of request slots (`SlotKVCache`).
Every scheduler step:

  1. admission — queued requests are prefilled (grouped by prompt-length
     bucket, padded with sentinel-masked rows so one jit serves the whole
     bucket) and inserted into free slots; a paged pool also gates
     admission on free KV pages. `policy="static"` instead gang-admits
     only when the pool is idle (the naive baseline the benchmark
     compares against);
  2. decode — one jitted chunk of `decode_chunk` steps runs as a
     `lax.scan` over `zoo.decode_step` + on-device sampling, with per-slot
     EOS / length early-exit masking.  The only host transfer is the
     (chunk, slots) emitted-token matrix once per chunk — not the
     per-token `np.asarray` sync of the old engine;
  3. harvest — emitted tokens are appended to their requests, finished
     slots are reset and returned to the free list.

Inactive lanes keep stepping inside a chunk (fixed-shape batch); their
cache writes land under their own lane's `kpos` mask and are wiped by the
slot reset on reuse, so they can never leak into a later request.

Sampling draws use per-slot, per-position keys (`sampler.fold_keys`): a
request's stochastic stream depends only on its seed and token index,
never on slot assignment or co-residents.

With `spec=SpecConfig(...)` the decode phase runs draft/verify cycles
instead of single-token chunks (`serve/spec`): a drafter proposes `k`
tokens per slot, one multi-token verify forward scores them all (one
packed-weight read for up to k+1 emitted tokens per slot), and
`SlotKVCache.rollback` commits the accepted prefix while sweeping the
rejected rows.  Greedy and "match"-mode stochastic requests emit the
exact non-speculative stream.

With prefix sharing on (`prefix_share`, default-auto on paged attention
pools) admission first walks a host-side radix index over token prefixes
(`serve/prefix`): full pages another request already cached are MAPPED
into the new slot's block table (refcount++, zero K/V movement), a
divergent tail page is copied (CoW), and only the unshared suffix is
prefilled — through `zoo.extend_step`, the multi-token decode write path
speculative verify already proved bitwise-equivalent to sequential
decode.  `prefill_chunk=N` additionally splits long suffixes into N-row
chunks advanced one per scheduler step, interleaved with decode chunks
(the decode jit sweeps mid-prefill slots' junk rows like a rejected
speculation), so a long admission no longer spikes co-resident TTFT.

With `mesh=...` the same loop runs sharded: the paged pool shards its
page axis and the block tables their slot axis (`sharding.cache_specs`),
params and per-slot decode state ride along replicated, and every jitted
cache update pins its output back to the pool layout — admission and
release stay host-side while page writes stay device-resident.  `n_pages`
defaults to `"auto"` (occupancy-derived provisioning) so admission
actually gates on free pages; pass `None` for full stripe capacity.
"""
from __future__ import annotations

import collections
import os
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import PackedHiNM
from repro.models import zoo
from repro.serve import sampler
from repro.serve import spec as spec_mod
from repro.serve.flightrec import resolve_flightrec
from repro.serve.kv import SlotKVCache
from repro.serve.prefix import PrefixIndex
from repro.serve.request import Request, RequestState, SamplingParams, ServeStats
from repro.serve.telemetry import resolve_telemetry


def resolve_packed_mode(arg="auto") -> str:
    """Resolve the serving weight-format knob to pack | dense | auto.

    ``REPRO_SERVE_PACKED`` (env) overrides the constructor argument:
    "1"/"pack"/"packed" packs every planned projection at engine
    construction (hinm_spmm becomes the projection path), "0"/"dense"
    unpacks PackedHiNM weights back to masked-dense (the fallback knob),
    "auto"/unset serves the params exactly as handed in."""
    env = os.environ.get("REPRO_SERVE_PACKED")
    if env is not None and env != "":
        arg = env
    if arg in (True, 1, "1", "pack", "packed", "true"):
        return "pack"
    if arg in (False, 0, "0", "dense", "false"):
        return "dense"
    if arg in (None, "", "auto"):
        return "auto"
    raise ValueError(f"unknown packed-weights mode {arg!r}")


def param_bytes(params) -> tuple[int, int]:
    """(packed, dense-equivalent) byte footprint of a param pytree."""
    packed = dense = 0
    for leaf in jax.tree.leaves(params, is_leaf=lambda x: isinstance(x, PackedHiNM)):
        if isinstance(leaf, PackedHiNM):
            packed += leaf.packed_bytes()
            dense += leaf.dense_bytes()
        else:
            b = leaf.size * jnp.dtype(leaf.dtype).itemsize
            packed += b
            dense += b
    return packed, dense


class Scheduler:
    def __init__(self, cfg, params, max_slots: int = 4, max_seq: int = 512,
                 decode_chunk: int = 8, rng_seed: int = 0,
                 policy: str = "continuous", cache_kw: dict | None = None,
                 page: int | None = 64, n_pages: int | str | None = "auto",
                 bucket: bool | None = None, bucket_min: int = 8, mesh=None,
                 spec: "spec_mod.SpecConfig | None" = None,
                 packed: bool | str = "auto", telemetry=None,
                 prefix_share: bool | str = "auto",
                 prefill_chunk: int | None = None,
                 async_admission: bool | str = "auto",
                 flightrec=None):
        if policy not in ("continuous", "static"):
            raise ValueError(f"unknown admission policy {policy!r}")
        self.cfg = cfg
        self.mesh = mesh
        # observability bundle (serve/telemetry): None/"auto" defers to
        # KNOBS.telemetry (off by default). The registry is live either
        # way — trace-time instruments (compile counts, kernel dispatch)
        # are free per step; `enabled` gates the wall-clock histograms
        # and request-lifecycle span recording on the hot path.
        self.telemetry = resolve_telemetry(telemetry)
        # flight recorder (serve/flightrec): the structured DECISION log
        # telemetry aggregates away — every admission, page, prefix, spec
        # and dispatch decision as a causally-keyed event stream that can
        # be dumped, replayed and diffed.  Off by default (None/False);
        # True builds a fresh recorder; an instance is shared as-is.
        # Chrome-trace instant bridging only engages when telemetry spans
        # are being recorded anyway — a bare recorder stays trace-free.
        self.flight = resolve_flightrec(
            flightrec,
            tracer=self.telemetry.tracer if self.telemetry.enabled else None)
        m = self.telemetry.registry
        self._m_prefill_traces = m.counter("serve_prefill_traces")
        self._m_admit_wait = m.histogram("serve_admission_wait_seconds")
        self._m_step = m.histogram("serve_decode_step_seconds")
        self._m_host_gap = m.histogram("serve_host_gap_seconds")
        self._m_spec_draft = m.histogram("serve_spec_draft_seconds")
        self._m_spec_verify = m.histogram("serve_spec_verify_seconds")
        self._m_spec_accept = m.histogram(
            "serve_spec_window_acceptance", lo=1e-4, growth=1.2, n_buckets=50)
        self._m_hit_tokens = m.counter("serve_prefix_hit_tokens")
        self._m_chunks = m.counter("serve_prefill_chunks")
        self._m_evictions = m.counter("serve_prefix_evictions")
        # dispatch-shape instruments (free: they count calls, not time).
        # serve_spec_dispatches = device dispatches issued by the spec
        # decode phase (the fused scan is ONE per step; the unfused chain
        # is 3-4 per cycle); serve_overlap_admissions = admission groups
        # whose prefill was dispatched while a decode chunk was in flight;
        # serve_inflight_syncs = blocking host syncs issued while a chunk
        # was in flight (the async path's regression canary — must be 0).
        self._m_spec_dispatch = m.counter("serve_spec_dispatches")
        self._m_overlap_admit = m.counter("serve_overlap_admissions")
        self._m_inflight_syncs = m.counter("serve_inflight_syncs")
        # serve-time weight packing (one-time, here at construction):
        # "pack" routes every planned q/k/v/o + MLP projection through
        # hinm_spmm for prefill, decode and spec-verify; "dense" is the
        # fallback knob (PackedHiNM unpacked to masked-dense matmuls)
        self.packed_mode = resolve_packed_mode(packed)
        if self.packed_mode == "pack":
            params = zoo.pack_params(cfg, params)
        elif self.packed_mode == "dense":
            params = zoo.unpack_params(cfg, params)
        if mesh is not None:
            # decode runs data-parallel over the mesh with replicated
            # weights (page/slot-axis sharding is the cache's job; tensor-
            # parallel serving would compose via param_specs); placing
            # params here keeps every jitted step on one device set
            params = jax.device_put(
                params, jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()))
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.decode_chunk = decode_chunk
        self.policy = policy
        self._vocab = cfg.vocab
        eos = getattr(cfg, "eos_id", -1)
        # out-of-vocab EOS (e.g. full-tokenizer ids on reduced test configs)
        # disables EOS termination rather than matching a wrong token
        self.default_eos = eos if 0 <= eos < cfg.vocab else -1
        # prompt-length bucketing: pad admission prefill to power-of-two
        # buckets (one jit per bucket, not per distinct prompt length).
        # Auto-off for recurrent families (pads would enter the state) and
        # windowed configs (the stripe ring-roll path assumes real
        # positions in every prefill row).
        can_bucket = zoo.supports_bucketed_prefill(cfg) and not cfg.window
        if bucket and not can_bucket:
            raise ValueError(f"{cfg.family!r} prefill cannot be length-bucketed")
        self.bucket = can_bucket if bucket is None else bucket
        self.bucket_min = bucket_min

        # --- speculative decoding (serve/spec) ---
        self.spec = spec
        self.drafter = None
        self.draft_kv = None
        self._draft_params = None
        if spec is not None:
            if not zoo.supports_spec_decode(cfg):
                raise ValueError(
                    f"{cfg.family!r} (window={cfg.window}) has no "
                    "speculative verify path")
            if spec.k < 1:
                raise ValueError("SpecConfig.k must be >= 1")
            if spec.k + 1 > max_seq:
                raise ValueError("SpecConfig.k + 1 exceeds max_seq")
            if spec.cycles is not None and spec.cycles < 1:
                raise ValueError("SpecConfig.cycles must be >= 1 (or None "
                                 "for the decode_chunk-derived default)")
            # fused scan: cycles are nearly free (no dispatch round-trip per
            # cycle), so one cycle per chunk step keeps the per-dispatch
            # token floor at the non-spec chunk's decode_chunk tokens/lane.
            # Unfused: every cycle costs 3-4 dispatches, so keep about one
            # chunk's worth of emitted rows per step.
            self._spec_cycles = (
                spec.cycles if spec.cycles is not None
                else (decode_chunk if spec.fused
                      else max(1, decode_chunk // (spec.k + 1))))
            d = spec.drafter
            if d == "ngram":
                d = spec_mod.NgramDrafter(spec.ngram)
            elif d == "model":
                d = spec_mod.ModelDrafter.from_zoo(cfg, rng_seed)
            if getattr(d, "kind", None) not in ("ngram", "model"):
                raise ValueError(
                    f"unknown drafter {d!r}: pass \"ngram\", \"model\", or a "
                    "Drafter instance with kind in ('ngram', 'model')")
            self.drafter = d
            if d.kind == "model":
                dparams = d.params
                if mesh is not None:
                    dparams = jax.device_put(
                        dparams, jax.sharding.NamedSharding(
                            mesh, jax.sharding.PartitionSpec()))
                self._draft_params = dparams
                # the draft model keeps its own stripe pool, rolled back in
                # lockstep with the target so both caches always hold the
                # same committed token stream
                self.draft_kv = SlotKVCache(d.cfg, max_slots, max_seq,
                                            mesh=mesh,
                                            metrics=self.telemetry.registry,
                                            metrics_labels={"pool": "draft"},
                                            flight=self.flight,
                                            flight_label="draft")

        self.kv = SlotKVCache(cfg, max_slots, max_seq, page=page,
                              n_pages=n_pages, mesh=mesh,
                              metrics=self.telemetry.registry,
                              flight=self.flight,
                              **(cache_kw or {}))
        # paged-attention kernel routing, resolved once per scheduler: the
        # family must expose the shared pool layout, and a page-sharded
        # pool defers to the SPMD gather path (the kernel is a single-
        # device program) unless KNOBS.paged_attn_sharded replicated the
        # pool. The jitted closures below trace under this resolved mode.
        from repro.perf_knobs import KNOBS

        self.paged_attn = KNOBS.paged_attn
        defer = None
        if not self.kv.paged:
            defer = "pool-not-paged"
        elif not zoo.supports_paged_attn_kernel(cfg):
            defer = "family-unsupported"
        elif self.kv.page_sharded and not KNOBS.paged_attn_sharded:
            defer = "page-sharded-pool"
        if defer is not None:
            self.paged_attn = "off"
            if KNOBS.paged_attn != "off":  # an actual downgrade, not a knob
                m.counter("serve_paged_attn_deferred",
                          labels={"reason": defer}).inc()
        if self.flight is not None:
            # the kernel-dispatch decision, attributable per scheduler:
            # what was asked for, what actually runs, and why it deferred
            self.flight.emit("dispatch", requested=KNOBS.paged_attn,
                             backend=self.paged_attn, defer=defer)
        # enc-dec pools cache the encoder output at fixed width t_enc
        # (pass cache_kw={"t_enc": ...} to right-size it for the workload)
        self._t_enc = (cache_kw or {}).get("t_enc") or max_seq

        # --- prefix sharing + chunked prefill (extension admission) ---
        # Both ride the multi-token decode write path (`zoo.extend_step`),
        # so they need a paged pool on a family whose K/V rows are
        # per-(token, position) pure — `zoo.supports_prefix_share` — and
        # the continuous policy (static gang admission is the naive
        # baseline and stays byte-for-byte the PR 2 pipeline).  "auto"
        # downgrades transparently; an explicit True raises loudly.
        can_extend = (self.kv.paged and zoo.supports_prefix_share(cfg)
                      and policy == "continuous")
        if prefix_share == "auto":
            prefix_share = can_extend
        if prefix_share and not can_extend:
            raise ValueError(
                f"prefix sharing needs a paged pool + a prefix-sharing "
                f"family under continuous admission (family={cfg.family!r}, "
                f"window={cfg.window}, paged={self.kv.paged}, "
                f"policy={policy!r})")
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError("prefill_chunk must be >= 1 (or None)")
            if not can_extend:
                raise ValueError(
                    f"chunked prefill needs a paged pool + a prefix-sharing "
                    f"family under continuous admission "
                    f"(family={cfg.family!r}, paged={self.kv.paged}, "
                    f"policy={policy!r})")
        self.prefix_share = bool(prefix_share)
        self.prefill_chunk = prefill_chunk
        self.prefix = (PrefixIndex(self.kv.page, flight=self.flight)
                       if self.prefix_share else None)

        # --- async (double-buffered) admission ---
        # While a decode chunk is in flight on device, the host prepares
        # the NEXT admission group — builds its padded token arrays and
        # dispatches its prefill — instead of idling until the chunk's
        # emit sync.  The group's first-token sync and slot arming happen
        # at the START of the next step (`_commit_admissions`), when its
        # prefill has long finished under the chunk.  Admission issues no
        # blocking sync while a chunk is in flight ("serve_inflight_syncs"
        # stays 0).  "auto" = on under the continuous policy; static gang
        # admission stays the synchronous naive baseline.
        if async_admission == "auto":
            async_admission = policy == "continuous"
        if async_admission and policy != "continuous":
            raise ValueError("async admission requires the continuous "
                             "admission policy (static gang admission is "
                             "the synchronous baseline)")
        self.async_admission = bool(async_admission)
        # overlapped groups awaiting their first-token sync, plus the slot
        # and page budget they reserved (commit must never find the pool
        # drained by an extension admission racing in between)
        self._pending_admits: list[tuple] = []
        self._pending_slots = 0
        self._pending_pages = 0
        self._chunk_in_flight = False
        # slots mid-extension-prefill: they hold pages but no decode lane
        self._prefilling: dict[int, Request] = {}
        self._extend_jits: dict[tuple, object] = {}

        self._queue: collections.deque[Request] = collections.deque()
        self._running: dict[int, Request] = {}
        self._active_host = np.zeros((max_slots,), bool)
        # host mirror of each slot's draft cap (spec stats accounting)
        self._keff_host = np.zeros((max_slots,), np.int64)
        self._build()
        self._reset_state(rng_seed)
        pb, db = param_bytes(params)
        self.stats = ServeStats(0.0, 0.0, 0, pb, db)
        if self.flight is not None:
            # configuration fingerprint: replaying a record on a scheduler
            # built differently diverges HERE, as the first event, instead
            # of surfacing as a deep token mystery
            self.flight.emit(
                "config", family=cfg.family, vocab=int(cfg.vocab),
                max_slots=max_slots, max_seq=max_seq,
                decode_chunk=decode_chunk, policy=policy,
                page=self.kv.page if self.kv.paged else None,
                n_pages=self.kv.n_pages if self.kv.paged else None,
                bucket=self.bucket, packed=self.packed_mode,
                paged_attn=self.paged_attn, prefix_share=self.prefix_share,
                prefill_chunk=self.prefill_chunk,
                async_admission=self.async_admission, rng_seed=rng_seed,
                sharded=self.mesh is not None,
                spec=None if spec is None else {
                    "k": spec.k, "fused": bool(spec.fused),
                    "cycles": self._spec_cycles,
                    "drafter": self.drafter.kind})

    # -- jitted kernels -----------------------------------------------------

    def _build(self) -> None:
        cfg, vocab, chunk = self.cfg, self._vocab, self.decode_chunk

        # `stochastic` is a static flag: all-greedy batches compile to a
        # plain argmax and skip the per-step top-k/top-p sort / categorical
        # draw (O(V log V) per lane — real money at full-tokenizer vocabs).
        # Every draw folds (request seed, token index) into the base key,
        # so streams are slot- and co-resident-independent.

        def prefill_fn(params, tokens, cache, embeds, base_key, seeds, temp,
                       topk, topp, n_rows, stochastic):
            self._m_prefill_traces.inc()  # runs at trace time only
            last, cache = zoo.prefill(params, cfg, tokens, cache,
                                      embeds=embeds, n_rows=n_rows)
            logits = zoo.logits_fn(params, cfg, last)[:, :vocab].astype(jnp.float32)
            if stochastic:
                keys = sampler.fold_keys(base_key, seeds,
                                         jnp.zeros_like(seeds))
                first = sampler.sample(keys, logits, temp, topk, topp)
            else:
                first = sampler.greedy(logits)
            return first, cache

        self._prefill = jax.jit(prefill_fn, static_argnames=("stochastic",))

        def chunk_fn(params, cache, tok, active, rem, temp, topk, topp, eos,
                     seeds, gens, base_key, protect, stochastic, guarded):
            # `guarded` (static) compiles in only while some slot is mid-
            # chunked-prefill: inactive lanes still advance pos and write
            # junk rows every scan step, which would corrupt a protected
            # slot's committed prefix — so the chunk ends with the same
            # sweep a fully-rejected speculation uses (keep=0 rewinds the
            # protected lanes, keep=chunk commits everyone else exactly
            # where the scan left them).
            pos_entry = zoo.cache_position(cfg, cache) if guarded else None

            def step(carry, _):
                cache, tok, active, rem, gens = carry
                logits, cache = zoo.decode_step(params, cfg, tok, cache)
                logits = logits[:, :vocab].astype(jnp.float32)
                if stochastic:
                    keys = sampler.fold_keys(base_key, seeds, gens)
                    nxt = sampler.sample(keys, logits, temp, topk, topp)
                else:
                    nxt = sampler.greedy(logits)
                emit = jnp.where(active, nxt, -1)
                gens = gens + active.astype(jnp.int32)
                rem = rem - active.astype(jnp.int32)
                hit_eos = active & (eos >= 0) & (nxt == eos)
                active = active & ~hit_eos & (rem > 0)
                tok = jnp.where(active, nxt, tok[:, 0])[:, None]
                return (cache, tok, active, rem, gens), emit

            from repro.perf_knobs import knobs

            with knobs(paged_attn=self.paged_attn):  # applies at trace time
                carry, emits = jax.lax.scan(
                    step, (cache, tok, active, rem, gens), None, length=chunk)
            if guarded:
                keep = jnp.where(protect, 0, jnp.int32(chunk))
                swept = zoo.cache_rollback(cfg, carry[0], None, pos_entry,
                                           keep, chunk)
                carry = (swept,) + carry[1:]
            if self.kv.shardings is not None:
                # pin the scanned cache back to its page/slot-axis layout so
                # chunked decode can't drift the pool off its shards
                carry = (jax.lax.with_sharding_constraint(
                    carry[0], self.kv.shardings),) + carry[1:]
            return carry + (emits,)

        self._chunk = jax.jit(chunk_fn, donate_argnums=(1, 2, 3, 4, 10),
                              static_argnames=("stochastic", "guarded"))

        def set_slot(tok, active, rem, temp, topk, topp, eos, seeds, gens,
                     keff, match, hist, hlen, slot, first, r, t, k, p, e, sd,
                     ke, mf, prow, plen):
            return (tok.at[slot, 0].set(first), active.at[slot].set(True),
                    rem.at[slot].set(r), temp.at[slot].set(t),
                    topk.at[slot].set(k), topp.at[slot].set(p),
                    eos.at[slot].set(e), seeds.at[slot].set(sd),
                    gens.at[slot].set(1), keff.at[slot].set(ke),
                    match.at[slot].set(mf), hist.at[slot].set(prow),
                    hlen.at[slot].set(plen))

        self._set_slot = jax.jit(
            set_slot, donate_argnums=tuple(range(13)))

        if self.spec is None:
            return

        s_width = self.spec.k + 1

        def verify_fn(params, cache, drafts, tok, active, rem, temp, topk,
                      topp, eos, seeds, gens, keff, match, hist, hlen,
                      base_key, stochastic, any_reject):
            from repro.perf_knobs import knobs

            pos0 = zoo.cache_position(cfg, cache)
            tokens = jnp.concatenate([tok, drafts], axis=1)
            with knobs(paged_attn=self.paged_attn):  # applies at trace time
                logits, cache, undo = zoo.verify_step(params, cfg, tokens,
                                                      cache)
            logits = logits[..., :vocab].astype(jnp.float32)
            emits, cnt, judged, tok, active, rem, gens = spec_mod.acceptance(
                logits, drafts, tok, base_key=base_key, seeds=seeds,
                gens=gens, temp=temp, topk=topk, topp=topp, eos=eos, rem=rem,
                active=active, k_eff=keff, match=match, stochastic=stochastic,
                any_reject=any_reject)
            hist, hlen = spec_mod.append_history(hist, hlen, emits, cnt)
            return (self.kv._constrain(cache), undo, pos0, emits, cnt, judged,
                    tok, active, rem, gens, hist, hlen)

        self._verify = jax.jit(verify_fn, donate_argnums=(1, 3, 4, 5, 11, 14, 15),
                               static_argnames=("stochastic", "any_reject"))

        if self.drafter.kind == "ngram":
            n = self.drafter.n

            def propose_fn(hist, hlen, tok):
                return spec_mod.ngram_propose(hist, hlen, tok,
                                              self.spec.k, n=n)

            self._propose = jax.jit(propose_fn)
        else:
            dcfg = self.drafter.cfg
            vcap = min(dcfg.vocab, vocab)
            k_draft = self.spec.k

            def draft_propose_fn(dparams, dcache, tok):
                dpos0 = zoo.cache_position(dcfg, dcache)

                def stp(carry, _):
                    dc, t = carry
                    lg, dc = zoo.decode_step(dparams, dcfg, t, dc)
                    nxt = jnp.argmax(
                        lg[:, :vcap], axis=-1).astype(jnp.int32)[:, None]
                    return (dc, nxt), nxt[:, 0]

                # s_width steps: the extra step writes the last draft's own
                # KV row, so the draft cache tracks the target row-for-row
                # and the same accept count rolls both back
                (dc, _), ds = jax.lax.scan(stp, (dcache, tok), None,
                                           length=s_width)
                return (jnp.moveaxis(ds, 0, 1)[:, :k_draft], dpos0,
                        self.draft_kv._constrain(dc))

            self._draft_propose = jax.jit(draft_propose_fn,
                                          donate_argnums=(1,))

            def draft_prefill_fn(dparams, tokens, dcache, n_rows):
                _, dc = zoo.prefill(dparams, dcfg, tokens, dcache,
                                    n_rows=n_rows)
                return dc

            self._draft_prefill = jax.jit(draft_prefill_fn)

        # --- fused draft/verify scan (SpecConfig.fused, the default) ---
        # The whole cycle — draft(k) -> multi-token verify -> accept ->
        # cache rollback -> history append — runs as ONE `lax.scan` body,
        # device-resident for `self._spec_cycles` cycles per dispatch,
        # with the draft cache carried through the scan alongside the
        # target cache.  The only host sync stays the stacked emit matrix
        # once per step, and the per-cycle dispatch chain (draft jit +
        # verify jit + 1-2 rollback dispatches) collapses to one dispatch.
        # The mid-prefill guard carries over by construction: `acceptance`
        # zeroes `cnt` for inactive lanes (chunked-prefill slots included),
        # so the in-scan rollback rewinds their junk verify rows with
        # keep=0 EVERY cycle and `append_history` writes them nothing —
        # exactly what SlotKVCache.rollback gave the unfused chain.
        cycles = self._spec_cycles
        k_spec = self.spec.k

        def _fused_cycle(params, cache, tok, active, rem, temp, topk, topp,
                         eos, seeds, gens, keff, match, hist, hlen,
                         base_key, drafts, stochastic, any_reject):
            pos0 = zoo.cache_position(cfg, cache)
            tokens = jnp.concatenate([tok, drafts], axis=1)
            logits, cache, undo = zoo.verify_step(params, cfg, tokens, cache)
            logits = logits[..., :vocab].astype(jnp.float32)
            emits, cnt, judged, tok, active, rem, gens = spec_mod.acceptance(
                logits, drafts, tok, base_key=base_key, seeds=seeds,
                gens=gens, temp=temp, topk=topk, topp=topp, eos=eos,
                rem=rem, active=active, k_eff=keff, match=match,
                stochastic=stochastic, any_reject=any_reject)
            hist, hlen = spec_mod.append_history(hist, hlen, emits, cnt)
            cache = zoo.cache_rollback(cfg, cache, undo, pos0, cnt, s_width)
            return cache, tok, active, rem, gens, hist, hlen, emits, cnt, judged

        if self.drafter.kind == "ngram":
            n_gram = self.drafter.n

            def spec_fused_fn(params, cache, tok, active, rem, temp, topk,
                              topp, eos, seeds, gens, keff, match, hist,
                              hlen, base_key, stochastic, any_reject):
                from repro.perf_knobs import knobs

                def cycle(carry, _):
                    cache, tok, active, rem, gens, hist, hlen = carry
                    drafts = spec_mod.ngram_propose(hist, hlen, tok, k_spec,
                                                    n=n_gram)
                    (cache, tok, active, rem, gens, hist, hlen, emits, cnt,
                     judged) = _fused_cycle(
                        params, cache, tok, active, rem, temp, topk, topp,
                        eos, seeds, gens, keff, match, hist, hlen, base_key,
                        drafts, stochastic, any_reject)
                    return ((cache, tok, active, rem, gens, hist, hlen),
                            (emits, cnt, judged))

                with knobs(paged_attn=self.paged_attn):  # trace-time knob
                    carry, outs = jax.lax.scan(
                        cycle, (cache, tok, active, rem, gens, hist, hlen),
                        None, length=cycles)
                cache, tok, active, rem, gens, hist, hlen = carry
                return (self.kv._constrain(cache), tok, active, rem, gens,
                        hist, hlen) + outs

            self._spec_fused = jax.jit(
                spec_fused_fn, donate_argnums=(1, 2, 3, 4, 10, 13, 14),
                static_argnames=("stochastic", "any_reject"))
        else:
            def spec_fused_fn(params, dparams, cache, dcache, tok, active,
                              rem, temp, topk, topp, eos, seeds, gens, keff,
                              match, hist, hlen, base_key, stochastic,
                              any_reject):
                from repro.perf_knobs import knobs

                def cycle(carry, _):
                    cache, dcache, tok, active, rem, gens, hist, hlen = carry
                    dpos0 = zoo.cache_position(dcfg, dcache)

                    def stp(c, _):
                        dc, t = c
                        lg, dc = zoo.decode_step(dparams, dcfg, t, dc)
                        nxt = jnp.argmax(
                            lg[:, :vcap], axis=-1).astype(jnp.int32)[:, None]
                        return (dc, nxt), nxt[:, 0]

                    (dcache, _), ds = jax.lax.scan(stp, (dcache, tok), None,
                                                   length=s_width)
                    drafts = jnp.moveaxis(ds, 0, 1)[:, :k_draft]
                    (cache, tok, active, rem, gens, hist, hlen, emits, cnt,
                     judged) = _fused_cycle(
                        params, cache, tok, active, rem, temp, topk, topp,
                        eos, seeds, gens, keff, match, hist, hlen, base_key,
                        drafts, stochastic, any_reject)
                    # same accept count rewinds the draft stripe in lockstep
                    dcache = zoo.cache_rollback(dcfg, dcache, None, dpos0,
                                                cnt, s_width)
                    return ((cache, dcache, tok, active, rem, gens, hist,
                             hlen), (emits, cnt, judged))

                with knobs(paged_attn=self.paged_attn):  # trace-time knob
                    carry, outs = jax.lax.scan(
                        cycle,
                        (cache, dcache, tok, active, rem, gens, hist, hlen),
                        None, length=cycles)
                cache, dcache, tok, active, rem, gens, hist, hlen = carry
                return (self.kv._constrain(cache),
                        self.draft_kv._constrain(dcache), tok, active, rem,
                        gens, hist, hlen) + outs

            self._spec_fused = jax.jit(
                spec_fused_fn, donate_argnums=(2, 3, 4, 5, 6, 12, 15, 16),
                static_argnames=("stochastic", "any_reject"))

    def _extend(self, width: int, sample: bool, stochastic: bool):
        """Jitted extension prefill, one trace per (width-bucket, sample,
        stochastic): write `width` token rows per lane from each slot's
        current position through the multi-token decode path, then sweep
        exactly like a speculation — lanes keep their true `keep` rows
        (padded chunk rows and every non-prefilling lane's junk writes
        rewind), so co-resident decode state is bitwise untouched.  With
        `sample` the final chunk also projects each lane's last real row
        and draws the first token with the SAME (seed, index 0) key
        admission prefill uses — chunked and monolithic admission emit
        identical streams."""
        key = (width, sample, stochastic)
        jit = self._extend_jits.get(key)
        if jit is None:
            cfg, vocab = self.cfg, self._vocab

            def extend_fn(params, cache, tokens, keep, base_key, seeds,
                          temp, topk, topp):
                from repro.perf_knobs import knobs

                pos0 = zoo.cache_position(cfg, cache)
                with knobs(paged_attn=self.paged_attn):  # trace-time knob
                    x, cache, undo = zoo.extend_step(params, cfg, tokens,
                                                     cache)
                cache = zoo.cache_rollback(cfg, cache, undo, pos0, keep,
                                           width)
                first = None
                if sample:
                    idx = jnp.maximum(keep - 1, 0)[:, None, None]
                    last = jnp.take_along_axis(x, idx, axis=1)[:, 0]
                    logits = zoo.logits_fn(params, cfg, last)[:, :vocab]
                    logits = logits.astype(jnp.float32)
                    if stochastic:
                        keys = sampler.fold_keys(base_key, seeds,
                                                 jnp.zeros_like(seeds))
                        first = sampler.sample(keys, logits, temp, topk, topp)
                    else:
                        first = sampler.greedy(logits)
                return self.kv._constrain(cache), first

            jit = self._extend_jits[key] = jax.jit(extend_fn,
                                                   donate_argnums=(1,))
        return jit

    def _reset_state(self, rng_seed: int) -> None:
        s = self.max_slots
        self._tok = jnp.zeros((s, 1), jnp.int32)
        self._active = jnp.zeros((s,), bool)
        self._rem = jnp.zeros((s,), jnp.int32)
        self._temp = jnp.zeros((s,), jnp.float32)
        self._topk = jnp.zeros((s,), jnp.int32)
        self._topp = jnp.zeros((s,), jnp.float32)
        self._eos = jnp.full((s,), -1, jnp.int32)
        self._seeds = jnp.zeros((s,), jnp.int32)
        self._gens = jnp.zeros((s,), jnp.int32)
        self._keff = jnp.zeros((s,), jnp.int32)
        self._match = jnp.ones((s,), bool)
        # per-slot token history (prompt + emitted): the n-gram drafter's
        # lookup corpus; sized for prompt + max_new, which max_seq bounds
        self._hist = jnp.zeros((s, self.max_seq), jnp.int32)
        self._hlen = jnp.zeros((s,), jnp.int32)
        # base PRNG key: never split — every draw folds in (request seed,
        # token index), so streams are reproducible per request
        self._key = jax.random.PRNGKey(rng_seed)
        if self.mesh is not None:
            # per-slot decode state rides along replicated: the chunk jit
            # then sees one device set (sharded pool + replicated state)
            rep = jax.sharding.NamedSharding(self.mesh,
                                             jax.sharding.PartitionSpec())
            (self._tok, self._active, self._rem, self._temp, self._topk,
             self._topp, self._eos, self._seeds, self._gens, self._keff,
             self._match, self._hist, self._hlen, self._key) = jax.device_put(
                (self._tok, self._active, self._rem, self._temp, self._topk,
                 self._topp, self._eos, self._seeds, self._gens, self._keff,
                 self._match, self._hist, self._hlen, self._key), rep)
        self._active_host[:] = False
        self._keff_host[:] = 0
        # end timestamp of the last decode dispatch+sync: the gap until
        # the next dispatch is pure host time (admission, harvest, python)
        self._last_sync = None

    def reset(self, rng_seed: int = 0) -> None:
        """Drop all queued/running requests and restore pristine state."""
        self._queue.clear()
        self._running.clear()
        self._prefilling.clear()
        self._pending_admits.clear()
        self._pending_slots = 0
        self._pending_pages = 0
        self._chunk_in_flight = False
        if self.prefix is not None:
            self.prefix = PrefixIndex(self.kv.page, flight=self.flight)
        self.kv.reset_all()
        if self.draft_kv is not None:
            self.draft_kv.reset_all()
        self._reset_state(rng_seed)
        self.stats = ServeStats(
            0.0, 0.0, 0, self.stats.packed_param_bytes, self.stats.dense_param_bytes)

    # -- request lifecycle --------------------------------------------------

    @property
    def prefill_traces(self) -> int:
        """Deprecated alias for the ``serve_prefill_traces`` registry
        counter (distinct XLA traces of the admission prefill — the
        compile-count column in benchmarks/serve_bench.py). Compile-count
        tracking lives in `self.telemetry.registry` with the other
        instruments; read the counter there instead."""
        warnings.warn(
            "Scheduler.prefill_traces is deprecated: read the "
            "'serve_prefill_traces' counter from the telemetry registry "
            "(scheduler.telemetry.registry) instead",
            DeprecationWarning, stacklevel=2)
        return int(self._m_prefill_traces.value)

    def clear_prefix_cache(self) -> int:
        """Drop every retained prefix (the index's page references).  Pages
        no live slot maps return to the free list immediately; shared ones
        follow when their last slot releases.  Returns pages freed now."""
        if self.prefix is None:
            return 0
        return self.prefix.clear(self.kv)

    def metrics_snapshot(self, include_global: bool = True) -> dict:
        """JSON-able snapshot of every instrument this scheduler feeds."""
        return self.telemetry.snapshot(include_global=include_global)

    @property
    def n_pending(self) -> int:
        return (len(self._queue) + len(self._prefilling)
                + len(self._running)
                + sum(len(rec[0]) for rec in self._pending_admits))

    def _cache_rows(self, req: Request) -> int:
        """Decoder-cache rows this request's prefill occupies. encdec embeds
        feed the encoder (cached separately as enc_out); vlm embeds are
        prepended to the decoder sequence."""
        extra = 0
        if req.embeds is not None and self.cfg.family != "encdec":
            extra = req.embeds.shape[0]
        return len(req.prompt) + extra

    def _reserve_rows(self, req: Request) -> int:
        """Cache rows this request may legally grow to (page budget)."""
        return self._cache_rows(req) + req.params.max_new_tokens

    def _bucket_len(self, n_tokens: int, extra: int) -> int:
        """Power-of-two prompt-length bucket, clamped so bucket + non-token
        rows (vlm embeds) still fit the prefill stripe."""
        b = self.bucket_min
        while b < n_tokens:
            b *= 2
        return max(n_tokens, min(b, self.max_seq - extra))

    def submit(self, req: Request) -> None:
        rows = self._cache_rows(req)
        if rows + req.params.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {req.rid}: {rows} prompt rows + max_new_tokens "
                f"{req.params.max_new_tokens} exceeds max_seq {self.max_seq}")
        if (self.kv.paged and self.kv.pages_needed(self._reserve_rows(req))
                > self.kv.n_alloc_pages):
            raise ValueError(
                f"request {req.rid}: needs more KV pages than the pool "
                f"allocates — raise n_pages")
        if (self.cfg.family == "encdec" and req.embeds is not None
                and req.embeds.shape[0] > self._t_enc):
            raise ValueError(
                f"request {req.rid}: {req.embeds.shape[0]} encoder frames "
                f"exceed the pool's t_enc {self._t_enc}")
        if req.params.spec_accept not in ("match", "reject"):
            raise ValueError(
                f"request {req.rid}: unknown spec_accept "
                f"{req.params.spec_accept!r}")
        req.state = RequestState.QUEUED
        req.submit_time = time.perf_counter()
        self._queue.append(req)
        if self.flight is not None:
            # the full admission schedule rides in this one event: prompt,
            # sampling params, seed, arrival — everything `flightrec.replay`
            # needs to rebuild the workload
            p = req.params
            self.flight.emit(
                "submit", rid=req.rid,
                prompt=[int(t) for t in req.prompt], arrival=req.arrival,
                max_new=p.max_new_tokens, temperature=float(p.temperature),
                top_k=int(p.top_k), top_p=float(p.top_p), eos=p.eos_id,
                seed=p.seed, spec_k=p.spec_k, spec_accept=p.spec_accept,
                embeds=req.embeds is not None)

    def _eff_eos(self, req: Request) -> int:
        if req.params.eos_id is not None:
            return req.params.eos_id if 0 <= req.params.eos_id < self._vocab else -1
        return self.default_eos

    def _eff_seed(self, req: Request) -> int:
        return req.params.seed if req.params.seed is not None else req.rid

    def _eff_keff(self, req: Request) -> int:
        if self.spec is None:
            return 0
        k = req.params.spec_k
        return self.spec.k if k is None else max(0, min(k, self.spec.k))

    def _finish(self, req: Request, finished: list[Request]) -> None:
        req.state = RequestState.FINISHED
        req.finish_time = time.perf_counter()
        eos = self._eff_eos(req)
        req.finish_reason = "eos" if (eos >= 0 and req.tokens and req.tokens[-1] == eos) else "length"
        self.stats.requests_finished += 1
        if req.finish_reason == "eos":
            self.stats.finished_at_eos += 1
        self.stats.observe_finish(req)
        if self.telemetry.enabled and req.first_token_time:
            # the decode span was opened when the lane armed (so abandoned
            # requests still export a valid, auto-closed span); requests
            # that finished at their first token never armed one
            open_span = next((s for s in reversed(req.spans)
                              if s.name == "decode" and s.t1 is None), None)
            if open_span is not None:
                self.telemetry.tracer.end(
                    open_span, req.finish_time, tokens=req.n_generated,
                    reason=req.finish_reason)
            else:
                self.telemetry.tracer.request_span(
                    req, "decode", req.first_token_time, req.finish_time,
                    tokens=req.n_generated, reason=req.finish_reason)
        if self.flight is not None:
            self.flight.emit("finish", rid=req.rid, reason=req.finish_reason,
                             n=req.n_generated, tokens=list(req.tokens))
        finished.append(req)

    def _extension_plan(self, req: Request):
        """(take_extension_path, PrefixMatch | None) for a queued request.

        A request extends in-pool — pages mapped first, suffix prefilled
        through `zoo.extend_step` — when its prompt hits the prefix index
        (shared pages make the stripe-scatter insert wrong: it would
        overwrite co-owned rows) or when chunking is on and the prompt
        exceeds one chunk.  Everything else (embeds requests, misses,
        short prompts) takes the classic bucketed group prefill."""
        if req.embeds is not None:
            return False, None
        m = None
        if self.prefix is not None:
            # always leave >= 1 row to prefill: the first sampled token
            # needs logits, and a fully-shared prompt would yield none
            m = self.prefix.match(req.prompt, len(req.prompt) - 1)
            if m.total_rows == 0:
                m = None
        chunked = (self.prefill_chunk is not None
                   and len(req.prompt) > self.prefill_chunk)
        return (m is not None or chunked), m

    def _ensure_pages(self, need: int, protect=()) -> bool:
        """Free-list pressure valve: retained prefixes are reclaimable
        memory, so a short admission evicts LRU index entries (pages only
        the index references) until `need` pages are free.  `protect`
        shields the pages of the admission's own pending match — evicting
        one would free a page its block table is about to map."""
        short = need - self.kv.n_free_pages
        if short > 0 and self.prefix is not None:
            freed = self.prefix.evict(self.kv, short, protect=protect)
            if freed:
                self._m_evictions.inc(freed)
        return need <= self.kv.n_free_pages

    def _admit(self, finished: list[Request]) -> None:
        if self.policy == "static" and self._running:
            return  # gang admission: wait for the whole pool to drain
        # overlapped admission groups hold reservations: their slots/pages
        # are drawn only at commit, so gate on what is genuinely left
        while self._queue and self.kv.n_free - self._pending_slots > 0:
            ext, m = self._extension_plan(self._queue[0])
            if ext:
                n_shared = len(m.page_ids) if m else 0
                need = (self.kv.pages_needed(
                    self._reserve_rows(self._queue[0])) - n_shared)
                protect = () if m is None else tuple(m.page_ids) + (
                    () if m.cow_src is None else (m.cow_src,))
                if not self._ensure_pages(need + self._pending_pages,
                                          protect):
                    return  # FIFO head waits for releases, no starvation
                self._start_extension(self._queue.popleft(), m)
                continue
            # group the queue head by (prompt-length bucket, embeds shape):
            # one batched prefill per group instead of k batch-1 prefills.
            # With bucketing on, every length in a bucket shares both the
            # group and the jit; without it the signature is the exact
            # length (fixed-batch compat stays a single (B, S) prefill).
            def sig(r):
                n = len(r.prompt)
                extra = self._cache_rows(r) - n
                return ((self._bucket_len(n, extra) if self.bucket else n),
                        None if r.embeds is None else r.embeds.shape)

            # paged pool: admission is also gated on free pages — a request
            # whose page budget doesn't fit waits at the queue head (FIFO,
            # no starvation) until releases (or prefix-cache eviction)
            # refill the free list
            head_reserve = self._reserve_rows(self._queue[0])
            if self.kv.paged:
                head_need = self.kv.pages_needed(head_reserve)
                self._ensure_pages(head_need + self._pending_pages)
                if (head_need + self._pending_pages > self.kv.n_free_pages):
                    return
            pages_left = self.kv.n_free_pages - self._pending_pages
            if self.kv.paged:
                pages_left -= self.kv.pages_needed(head_reserve)
            group = [self._queue.popleft()]
            while (self._queue
                   and len(group) < self.kv.n_free - self._pending_slots
                   and sig(self._queue[0]) == sig(group[0])
                   and not self._extension_plan(self._queue[0])[0]):
                if self.kv.paged:
                    need = self.kv.pages_needed(
                        self._reserve_rows(self._queue[0]))
                    if need > pages_left:
                        break
                    pages_left -= need
                group.append(self._queue.popleft())
            self._admit_group(group, finished)

    def _admit_group(self, group: list[Request], finished: list[Request]) -> None:
        """Prefill an admission group and arm its slots.

        The host work (array building), the prefill dispatch and the
        first-token sync used to be one synchronous block.  They are now
        two phases: **prepare** (everything up to and including the
        dispatch — no sync) and **commit** (`_commit_group`: the one
        first-token sync per group, then slot arming).  Synchronous mode
        commits immediately; with async admission a group prepared while
        a decode chunk is in flight is queued and committed at the start
        of the next step, its prefill having overlapped the chunk."""
        k = len(group)
        t0 = time.perf_counter()  # host array prep counts as prefill work
        for req in group:
            req.state = RequestState.PREFILLING
            req.admit_time = t0
        if self.bucket:
            # pad every prompt to the group's shared length bucket and the
            # group itself to a power-of-two width: one jit per
            # (bucket, width-bucket) instead of one per distinct shape.
            # Padded rows/lanes are sentinel-masked and discarded.
            n0 = len(group[0].prompt)
            s_b = self._bucket_len(n0, self._cache_rows(group[0]) - n0)
            k_b = 1
            while k_b < k:
                k_b *= 2
            tokens = np.zeros((k_b, s_b), np.int32)
            n_rows = np.zeros((k_b,), np.int32)
            d_rows = np.zeros((k_b,), np.int32)
            for i in range(k_b):
                r = group[min(i, k - 1)]
                tokens[i, : len(r.prompt)] = r.prompt
                n_rows[i] = self._cache_rows(r)
                d_rows[i] = len(r.prompt)
            tokens = jnp.asarray(tokens)
            n_rows_dev = jnp.asarray(n_rows)
            d_rows_dev = jnp.asarray(d_rows)
            def pad(a):
                return (np.concatenate([a, np.repeat(a[-1:], k_b - k, axis=0)])
                        if k_b > k else a)

            embeds = (None if group[0].embeds is None
                      else jnp.asarray(pad(np.stack([r.embeds for r in group]))))
            temps = pad(np.asarray([r.params.temperature for r in group],
                                   np.float32))
            topks = pad(np.asarray([r.params.top_k for r in group], np.int32))
            topps = pad(np.asarray([r.params.top_p for r in group], np.float32))
            seeds = pad(np.asarray([self._eff_seed(r) for r in group], np.int32))
        else:
            k_b = k
            tokens = jnp.asarray(np.stack([r.prompt for r in group]), jnp.int32)
            n_rows_dev = None
            d_rows_dev = None
            embeds = (None if group[0].embeds is None
                      else jnp.asarray(np.stack([r.embeds for r in group])))
            temps = np.asarray([r.params.temperature for r in group], np.float32)
            topks = np.asarray([r.params.top_k for r in group], np.int32)
            topps = np.asarray([r.params.top_p for r in group], np.float32)
            seeds = np.asarray([self._eff_seed(r) for r in group], np.int32)
        with self.telemetry.annotation("serve_prefill"):
            first, cache_k = self._prefill(
                self.params, tokens, self.kv.template(k_b), embeds, self._key,
                jnp.asarray(seeds), jnp.asarray(temps), jnp.asarray(topks),
                jnp.asarray(topps), n_rows_dev,
                stochastic=bool((temps[:k] > 0).any()))
        draft_cache_k = None
        if self.draft_kv is not None:
            # the draft model prefills the same prompts into its own pool
            # (token rows only: a modality frontend is the target's)
            draft_cache_k = self._draft_prefill(
                self._draft_params, tokens, self.draft_kv.template(k_b),
                d_rows_dev)
        t1 = time.perf_counter()
        self.stats.prefill_rows += sum(self._cache_rows(r) for r in group)
        if self.telemetry.enabled:
            blen = int(tokens.shape[1])
            tr = self.telemetry.tracer
            self.telemetry.registry.histogram(
                "serve_prefill_seconds",
                labels={"bucket": str(blen)}).observe(t1 - t0)
            tr.span("scheduler", f"prefill[b{blen}]", t0, t1,
                    requests=k, bucket=blen)
            for req in group:
                self._m_admit_wait.observe(req.admit_time - req.submit_time)
                tr.request_span(req, "queued", req.submit_time, req.admit_time)
                tr.request_span(req, f"prefill[b{blen}]", t0, t1)
        if self.flight is not None:
            # one event per admission group: membership, bucket geometry,
            # and whether the prepare phase overlapped an in-flight chunk
            # (its `commit` events then land at the NEXT step's start —
            # the async prepare/commit pairing, visible in the stream)
            self.flight.emit(
                "admit", group=[r.rid for r in group],
                bucket=int(tokens.shape[1]), width=k_b,
                overlap=bool(self.async_admission and self._chunk_in_flight))
        rec = (group, first, cache_k, draft_cache_k)
        if self.async_admission and self._chunk_in_flight:
            # overlapped: the prepare window ran UNDER the in-flight decode
            # chunk, so its wall time is hidden device-side — charging it
            # to prefill_seconds as well would double-count the makespan.
            # Reserve the group's slots/pages and hand off to next step's
            # `_commit_admissions` (no sync here — that's the whole point).
            self._pending_admits.append(rec)
            self._pending_slots += k
            if self.kv.paged:
                self._pending_pages += sum(
                    self.kv.pages_needed(self._reserve_rows(r))
                    for r in group)
            self._m_overlap_admit.inc()
            return
        self.stats.prefill_seconds += t1 - t0
        self._commit_group(rec, finished)

    def _commit_admissions(self, finished: list[Request]) -> None:
        """Land every admission group prepared under the previous decode
        chunk: one first-token sync per group (the prefill itself finished
        while the chunk ran), then the usual slot arming."""
        if not self._pending_admits:
            return
        pending, self._pending_admits = self._pending_admits, []
        self._pending_slots = 0
        self._pending_pages = 0
        for rec in pending:
            self._commit_group(rec, finished)

    def _commit_group(self, rec: tuple, finished: list[Request]) -> None:
        group, first, cache_k, draft_cache_k = rec
        tc0 = time.perf_counter()
        if self._chunk_in_flight:  # canary: committing mid-flight blocks
            self._m_inflight_syncs.inc()
        first_np = np.asarray(first)  # one sync per admitted group (= TTFT)
        now = time.perf_counter()
        for row, req in enumerate(group):
            p = req.params
            eos = self._eff_eos(req)
            first_i = int(first_np[row])
            req.tokens.append(first_i)
            req.first_token_time = now
            self.stats.tokens_generated += 1
            if (eos >= 0 and first_i == eos) or p.max_new_tokens <= 1:
                # finished at its first token: never touch the slot pool —
                # acquiring a slot just to release it would dispatch a full
                # template reset into a slot that was never written
                if self.flight is not None:
                    self.flight.emit("commit", rid=req.rid, slot=None,
                                     first=first_i, finished=True)
                self._finish(req, finished)
                continue
            slot = self.kv.acquire()
            self.kv.insert(slot, cache_k, self._cache_rows(req), row=row,
                           reserve=self._reserve_rows(req))
            if self.prefix is not None and req.embeds is None:
                # index this prompt's full pages (retention refs): the
                # next identical prefix maps them instead of recomputing
                self.prefix.register(req.prompt, self.kv.slot_pages(slot),
                                     self.kv)
            if self.draft_kv is not None:
                dslot = self.draft_kv.acquire()
                assert dslot == slot, "draft pool diverged from target pool"
                self.draft_kv.insert(slot, draft_cache_k, len(req.prompt),
                                     row=row,
                                     reserve=len(req.prompt) + p.max_new_tokens)
            keff = self._eff_keff(req)
            # full-prompt drafter history (shared-prefix rows included)
            prow, hl = spec_mod.seed_history(req.prompt, first_i,
                                             self.max_seq)
            (self._tok, self._active, self._rem, self._temp, self._topk,
             self._topp, self._eos, self._seeds, self._gens, self._keff,
             self._match, self._hist, self._hlen) = self._set_slot(
                self._tok, self._active, self._rem, self._temp, self._topk,
                self._topp, self._eos, self._seeds, self._gens, self._keff,
                self._match, self._hist, self._hlen, slot, first_i,
                p.max_new_tokens - 1, p.temperature, p.top_k, p.top_p, eos,
                self._eff_seed(req), keff, p.spec_accept == "match",
                jnp.asarray(prow), hl)
            self._active_host[slot] = True
            self._keff_host[slot] = keff
            req.state = RequestState.DECODING
            req.slot = slot
            self._running[slot] = req
            if self.flight is not None:
                self.flight.emit("commit", rid=req.rid, slot=slot,
                                 first=first_i, finished=False)
            if self.telemetry.enabled:
                # open-span decode lifecycle: closed by `_finish`, or
                # auto-closed at export if the request is abandoned
                req.spans.append(self.telemetry.tracer.begin(
                    f"req{req.rid}", "decode", t0=now, rid=req.rid))
        # the whole commit — sync, pool inserts, slot arming — is admission
        # work; leaving the arming loop outside the window misreports it as
        # host gap (it dominated host_overhead_fraction at bench scale)
        self.stats.prefill_seconds += time.perf_counter() - tc0

    def _start_extension(self, req: Request, m) -> None:
        """Begin an extension admission: acquire a slot, map the shared
        prefix pages (refcount++) and the fresh suffix pages into its block
        table — copying only a divergent tail page — and queue the slot
        for per-step suffix prefill (`_advance_prefill`).  No stripe
        scatter happens: shared pages are co-owned and must not be
        overwritten; fresh rows are written in-pool by `zoo.extend_step`."""
        now = time.perf_counter()
        req.state = RequestState.PREFILLING
        req.admit_time = now
        slot = self.kv.acquire()
        shared = m.page_ids if m is not None else []
        self.kv.map_slot(
            slot, shared, len(shared) * self.kv.page,
            self._reserve_rows(req),
            cow_src=m.cow_src if m is not None else None,
            cow_rows=m.cow_rows if m is not None else 0)
        if self.draft_kv is not None:
            # the draft pool acquires in lockstep NOW (so slot ids stay
            # aligned with the target pool); its stripe is prefilled in
            # one shot when the admission completes
            dslot = self.draft_kv.acquire()
            assert dslot == slot, "draft pool diverged from target pool"
        hit = m.total_rows if m is not None else 0
        req.prefix_hit_tokens = hit
        req.prefill_cursor = hit
        self.stats.prefix_hit_tokens += hit
        if hit:
            self._m_hit_tokens.inc(hit)
        req.slot = slot
        self._prefilling[slot] = req
        if self.flight is not None:
            # the prefix decision this admission rode: which pages were
            # mapped by reference, which page was CoW-copied, how many
            # prompt rows never re-prefill
            self.flight.emit(
                "ext_admit", rid=req.rid, slot=slot,
                shared=[int(p) for p in shared],
                cow_src=None if m is None else m.cow_src,
                cow_rows=0 if m is None else m.cow_rows, hit=hit)
        if self.telemetry.enabled:
            self._m_admit_wait.observe(req.admit_time - req.submit_time)
            self.telemetry.tracer.request_span(
                req, "queued", req.submit_time, req.admit_time)

    def _advance_prefill(self, finished: list[Request]) -> None:
        """One extension-prefill chunk for EVERY mid-admission slot, in a
        single batched dispatch: each prefilling lane writes its next
        `min(remaining, prefill_chunk)` suffix rows from its current
        position; every other lane's junk writes are swept in-jit
        (keep=0), so decode state is bitwise untouched.  Lanes whose
        suffix completes sample their first token from the chunk's last
        real row — same logits, same fold keys as monolithic prefill —
        and graduate to decode."""
        if not self._prefilling:
            return
        chunk_w = self.prefill_chunk or self.max_seq
        items = []
        for slot, req in self._prefilling.items():
            remaining = len(req.prompt) - req.prefill_cursor
            width = min(remaining, chunk_w)
            items.append((slot, req, width, width == remaining))
        w_max = max(w for _, _, w, _ in items)
        w_b = self._bucket_len(w_max, 0) if self.bucket else w_max
        sample = any(last for _, _, _, last in items)
        stochastic = any(last and req.params.temperature > 0
                         for _, req, _, last in items)
        s = self.max_slots
        tokens = np.zeros((s, w_b), np.int32)
        keep = np.zeros((s,), np.int32)
        temps = np.zeros((s,), np.float32)
        topks = np.zeros((s,), np.int32)
        topps = np.zeros((s,), np.float32)
        seeds = np.zeros((s,), np.int32)
        for slot, req, width, _ in items:
            cur = req.prefill_cursor
            tokens[slot, :width] = req.prompt[cur:cur + width]
            keep[slot] = width
            temps[slot] = req.params.temperature
            topks[slot] = req.params.top_k
            topps[slot] = req.params.top_p
            seeds[slot] = self._eff_seed(req)
        t0 = time.perf_counter()
        with self.telemetry.annotation("serve_prefill_chunk"):
            self.kv.cache, first = self._extend(w_b, sample, stochastic)(
                self.params, self.kv.cache, jnp.asarray(tokens),
                jnp.asarray(keep), self._key, jnp.asarray(seeds),
                jnp.asarray(temps), jnp.asarray(topks), jnp.asarray(topps))
            if sample and self._chunk_in_flight:  # canary: see _commit_group
                self._m_inflight_syncs.inc()
            first_np = np.asarray(first) if sample else None  # one sync
        now = time.perf_counter()
        self.stats.prefill_seconds += now - t0
        n_lanes = len(items)
        self._m_chunks.inc(n_lanes)
        if self.telemetry.enabled:
            tr = self.telemetry.tracer
            self.telemetry.registry.histogram(
                "serve_prefill_chunk_seconds",
                labels={"bucket": str(w_b)}).observe(now - t0, n=n_lanes)
            tr.span("scheduler", f"prefill_chunk[b{w_b}]", t0, now,
                    lanes=n_lanes, bucket=w_b)
        for slot, req, width, last in items:
            req.prefill_cursor += width
            req.prefill_chunks += 1
            self.stats.prefill_chunks += 1
            self.stats.prefill_rows += width
            self.kv.slot_len[slot] += width
            if self.flight is not None:
                self.flight.emit("chunk", rid=req.rid, slot=slot,
                                 width=width, cursor=req.prefill_cursor,
                                 last=last)
            if self.telemetry.enabled:
                self.telemetry.tracer.request_span(
                    req, f"prefill_chunk[b{w_b}]", t0, now)
            if last:
                self._complete_admission(slot, req, int(first_np[slot]),
                                         now, finished)

    def _complete_admission(self, slot: int, req: Request, first_i: int,
                            now: float, finished: list[Request]) -> None:
        """Graduate a finished extension admission to decode: register its
        full prompt pages in the prefix index (retention refs — this is
        what a LATER identical prefix hits), prefill the draft stripe in
        one shot under spec, and arm the decode lane — or finish outright
        on a first-token EOS / single-token budget."""
        p = req.params
        eos = self._eff_eos(req)
        req.tokens.append(first_i)
        req.first_token_time = now
        self.stats.tokens_generated += 1
        del self._prefilling[slot]
        if self.prefix is not None:
            self.prefix.register(req.prompt, self.kv.slot_pages(slot),
                                 self.kv)
        if self.telemetry.enabled:
            self.telemetry.tracer.request_span(
                req, "prefill", req.admit_time, now,
                hit_tokens=req.prefix_hit_tokens, chunks=req.prefill_chunks)
        if (eos >= 0 and first_i == eos) or p.max_new_tokens <= 1:
            # finished at its first token: unlike the classic path this
            # slot exists (pages were mapped before prefill), so release
            # it — registered pages survive via the index's references
            if self.flight is not None:
                self.flight.emit("graduate", rid=req.rid, slot=slot,
                                 first=first_i, finished=True)
            self.kv.release(slot)
            if self.draft_kv is not None:
                self.draft_kv.release(slot)
            self._finish(req, finished)
            return
        if self.draft_kv is not None:
            n = len(req.prompt)
            s_b = self._bucket_len(n, 0) if self.bucket else n
            dtok = np.zeros((1, s_b), np.int32)
            dtok[0, :n] = req.prompt
            d_rows = jnp.asarray(np.asarray([n], np.int32)) if self.bucket else None
            dcache = self._draft_prefill(self._draft_params,
                                         jnp.asarray(dtok),
                                         self.draft_kv.template(1), d_rows)
            self.draft_kv.insert(slot, dcache, n, row=0,
                                 reserve=n + p.max_new_tokens)
        keff = self._eff_keff(req)
        # full-prompt drafter history: a prefix-shared admission prefilled
        # only its unshared suffix, but the n-gram corpus must still hold
        # the page-mapped prefix rows (spec_mod.seed_history's contract)
        prow, hl = spec_mod.seed_history(req.prompt, first_i, self.max_seq)
        (self._tok, self._active, self._rem, self._temp, self._topk,
         self._topp, self._eos, self._seeds, self._gens, self._keff,
         self._match, self._hist, self._hlen) = self._set_slot(
            self._tok, self._active, self._rem, self._temp, self._topk,
            self._topp, self._eos, self._seeds, self._gens, self._keff,
            self._match, self._hist, self._hlen, slot, first_i,
            p.max_new_tokens - 1, p.temperature, p.top_k, p.top_p, eos,
            self._eff_seed(req), keff, p.spec_accept == "match",
            jnp.asarray(prow), hl)
        self._active_host[slot] = True
        self._keff_host[slot] = keff
        req.state = RequestState.DECODING
        self._running[slot] = req
        if self.flight is not None:
            self.flight.emit("graduate", rid=req.rid, slot=slot,
                             first=first_i, finished=False)
        if self.telemetry.enabled:
            # open-span decode lifecycle, same contract as `_commit_group`
            req.spans.append(self.telemetry.tracer.begin(
                f"req{req.rid}", "decode", t0=now, rid=req.rid))

    def _overlap_admit(self, finished: list[Request]) -> None:
        """Double-buffered admission: called between a decode dispatch and
        its emit sync, while the chunk is still in flight on device.  The
        host prepares the next admission group (array building + prefill
        dispatch — `_admit_group` defers its sync under the in-flight
        flag) and starts extension admissions, all of which queue behind
        the chunk instead of serializing after it."""
        if not self.async_admission:
            return
        self._chunk_in_flight = True
        try:
            self._admit(finished)
        finally:
            self._chunk_in_flight = False

    def _release_slot(self, slot: int) -> None:
        self.kv.release(slot)
        if self.draft_kv is not None:
            self.draft_kv.release(slot)
        self._running.pop(slot)
        self._active_host[slot] = False
        self._keff_host[slot] = 0

    def _decode_and_harvest(self, finished: list[Request]) -> None:
        if not self._active_host.any():
            return
        if self.spec is not None:
            self._spec_decode_and_harvest(finished)
            return
        stochastic = any(r.params.temperature > 0 for r in self._running.values())
        t0 = time.perf_counter()
        if self.telemetry.enabled and self._last_sync is not None:
            self._m_host_gap.observe(t0 - self._last_sync)
        # while a slot is mid-chunked-prefill the chunk guards its rows:
        # inactive lanes' junk writes are swept in-jit (a fully-rejected
        # speculation for the protected lanes)
        guarded = bool(self._prefilling)
        protect = np.zeros((self.max_slots,), bool)
        if guarded:
            protect[list(self._prefilling)] = True
        with self.telemetry.annotation("serve_decode_chunk",
                                       step=self.stats.decode_steps):
            (self.kv.cache, self._tok, self._active, self._rem, self._gens,
             emits) = self._chunk(
                self.params, self.kv.cache, self._tok, self._active, self._rem,
                self._temp, self._topk, self._topp, self._eos, self._seeds,
                self._gens, self._key, jnp.asarray(protect),
                stochastic=stochastic, guarded=guarded)
            self._overlap_admit(finished)  # chunk in flight: prep admission
            emits = np.asarray(emits)             # (chunk, slots) — one sync
            active_np = np.asarray(self._active)
        t1 = time.perf_counter()
        self.stats.decode_seconds += t1 - t0
        self.stats.decode_steps += self.decode_chunk
        self.stats.step_time_hist.observe((t1 - t0) / self.decode_chunk,
                                          n=self.decode_chunk)
        if self.telemetry.enabled:
            self._m_step.observe((t1 - t0) / self.decode_chunk,
                                 n=self.decode_chunk)
            self.telemetry.tracer.span(
                "scheduler", "decode_chunk", t0, t1, steps=self.decode_chunk,
                lanes=int(self._active_host.sum()))
        self._last_sync = t1

        width = np.maximum((emits >= 0).sum(axis=1), 1)  # active lanes/step
        for slot, req in list(self._running.items()):
            col = emits[:, slot]
            mine = col >= 0
            new = col[mine].tolist()
            req.tokens.extend(new)
            req.shared_decode_steps += float((1.0 / width)[mine].sum())
            self.stats.tokens_generated += len(new)
            self.stats.decode_tokens += len(new)
            # slot_len = actual cache rows: prompt rows + one row per
            # decode-emitted token (each emitted token implies the step that
            # wrote the PREVIOUS token's KV; the newest token's row lands on
            # the step that feeds it back)
            self.kv.slot_len[slot] += len(new)
            cap = self.kv.slot_capacity(slot)
            assert self.kv.slot_len[slot] <= cap, (
                f"slot {slot}: {self.kv.slot_len[slot]} cache rows exceed "
                f"the {cap}-row reservation — accounting drift would "
                f"corrupt a neighbor page")
            if self.flight is not None and new:
                self.flight.emit("emit", rid=req.rid, slot=slot, tokens=new)
            if not active_np[slot]:
                self._finish(req, finished)
                self._release_slot(slot)

    def _spec_decode_and_harvest(self, finished: list[Request]) -> None:
        """Draft/verify decode: each cycle proposes k draft tokens per slot,
        verifies all of them with ONE target forward, commits the accepted
        prefix and rolls the rejected rows back — up to k+1 tokens per slot
        per packed-weight read.  Like the chunk loop, the only host sync is
        the stacked emit matrix once per step.  With `SpecConfig.fused`
        (default) all cycles additionally collapse into a single jitted
        `lax.scan` dispatch; `fused=False` keeps the per-cycle dispatch
        chain as the token-identical debugging fallback."""
        s_width = self.spec.k + 1
        cycles = self._spec_cycles
        stochastic = any(r.params.temperature > 0 for r in self._running.values())
        # static specialization: the rejection-sampling pipeline only
        # compiles in when some stochastic lane actually opted into it
        any_reject = any(r.params.temperature > 0
                         and r.params.spec_accept == "reject"
                         for r in self._running.values())
        tele = self.telemetry.enabled
        t0 = time.perf_counter()
        if tele and self._last_sync is not None:
            self._m_host_gap.observe(t0 - self._last_sync)
        dp0, da0 = self.stats.draft_proposed, self.stats.draft_accepted
        if self.spec.fused:
            # ONE dispatch runs all `cycles` draft/verify cycles device-
            # resident (draft cache carried through the scan); the only
            # sync stays the stacked emit matrix below
            with self.telemetry.annotation("serve_spec_fused",
                                           step=self.stats.decode_steps):
                if self.draft_kv is not None:
                    (self.kv.cache, self.draft_kv.cache, self._tok,
                     self._active, self._rem, self._gens, self._hist,
                     self._hlen, emits_dev, cnts_dev,
                     judged_dev) = self._spec_fused(
                        self.params, self._draft_params, self.kv.cache,
                        self.draft_kv.cache, self._tok, self._active,
                        self._rem, self._temp, self._topk, self._topp,
                        self._eos, self._seeds, self._gens, self._keff,
                        self._match, self._hist, self._hlen, self._key,
                        stochastic=stochastic, any_reject=any_reject)
                else:
                    (self.kv.cache, self._tok, self._active, self._rem,
                     self._gens, self._hist, self._hlen, emits_dev, cnts_dev,
                     judged_dev) = self._spec_fused(
                        self.params, self.kv.cache, self._tok, self._active,
                        self._rem, self._temp, self._topk, self._topp,
                        self._eos, self._seeds, self._gens, self._keff,
                        self._match, self._hist, self._hlen, self._key,
                        stochastic=stochastic, any_reject=any_reject)
            self._m_spec_dispatch.inc()
            self.kv.note_scan_rollbacks(cycles)
            if self.draft_kv is not None:
                self.draft_kv.note_scan_rollbacks(cycles)
            self._overlap_admit(finished)  # scan in flight: prep admission
        else:
            emits_acc, cnts_acc, judged_acc = [], [], []
            for _ in range(cycles):
                # the draft/verify split is dispatch-side wall time: the
                # only device sync stays the stacked emit matrix below, so
                # these windows attribute host/dispatch cost, with device
                # compute folded into whichever dispatch first blocks on it
                td0 = time.perf_counter()
                with self.telemetry.annotation("serve_spec_draft"):
                    if self.draft_kv is not None:
                        drafts, dpos0, self.draft_kv.cache = self._draft_propose(
                            self._draft_params, self.draft_kv.cache, self._tok)
                    else:
                        drafts = self._propose(self._hist, self._hlen, self._tok)
                        dpos0 = None
                td1 = time.perf_counter()
                # draft dispatch wall time is accounted on its own so the
                # bench's decode_step_us (target verify cost) and host-gap
                # columns don't each absorb it a second time
                self.stats.spec_draft_seconds += td1 - td0
                with self.telemetry.annotation("serve_spec_verify"):
                    (self.kv.cache, undo, pos0, emits, cnt, judged, self._tok,
                     self._active, self._rem, self._gens, self._hist,
                     self._hlen) = self._verify(
                        self.params, self.kv.cache, drafts, self._tok, self._active,
                        self._rem, self._temp, self._topk, self._topp, self._eos,
                        self._seeds, self._gens, self._keff, self._match, self._hist,
                        self._hlen, self._key, stochastic=stochastic,
                        any_reject=any_reject)
                    self.kv.rollback(pos0, cnt, s_width, undo=undo)
                    if dpos0 is not None:
                        self.draft_kv.rollback(dpos0, cnt, s_width)
                # unfused dispatch chain per cycle: draft + verify + target
                # rollback (+ draft rollback under a model drafter)
                self._m_spec_dispatch.inc(3 if dpos0 is None else 4)
                if tele:
                    td2 = time.perf_counter()
                    self._m_spec_draft.observe(td1 - td0)
                    self._m_spec_verify.observe(td2 - td1)
                    self.telemetry.tracer.span("scheduler", "spec_draft", td0, td1)
                    self.telemetry.tracer.span("scheduler", "spec_verify", td1, td2)
                emits_acc.append(emits)
                cnts_acc.append(cnt)
                judged_acc.append(judged)
            self._overlap_admit(finished)  # dispatches queued: prep admission
            emits_dev = jnp.stack(emits_acc)
            cnts_dev = jnp.stack(cnts_acc)
            judged_dev = jnp.stack(judged_acc)
        emits_np = np.asarray(emits_dev)   # (cycles, slots, k+1) — one sync
        cnts_np = np.asarray(cnts_dev)     # (cycles, slots)
        judged_np = np.asarray(judged_dev)  # (cycles, slots)
        active_np = np.asarray(self._active)
        t1 = time.perf_counter()
        self.stats.decode_seconds += t1 - t0
        self.stats.decode_steps += cycles
        self.stats.verify_steps += cycles
        self.stats.step_time_hist.observe((t1 - t0) / cycles, n=cycles)
        if tele:
            self._m_step.observe((t1 - t0) / cycles, n=cycles)
            self.telemetry.tracer.span(
                "scheduler", "spec_cycles", t0, t1, cycles=cycles,
                lanes=int(self._active_host.sum()))
        self._last_sync = t1

        # lanes that emitted in a cycle share that cycle's weight read
        width = np.maximum((cnts_np > 0).sum(axis=1), 1)
        for slot, req in list(self._running.items()):
            cnts = cnts_np[:, slot]
            rode = cnts > 0
            col = emits_np[:, slot, :].reshape(-1)
            new = col[col >= 0].tolist()
            # acceptance accounting counts only draft verdicts that reached
            # the stream (accepted drafts + an emitted correction's
            # rejection): drafts past an EOS or budget cut were never
            # judgeable, so counting them would misreport truncated cycles
            # as rejections (`judged` from spec.acceptance)
            proposed = int(judged_np[:, slot].sum())
            req.tokens.extend(new)
            req.shared_decode_steps += float((1.0 / width)[rode].sum())
            accepted = int(np.maximum(cnts - 1, 0).sum())
            req.spec_verify_steps += int(rode.sum())
            req.spec_proposed += proposed
            req.spec_accepted += accepted
            self.stats.lane_verify_steps += int(rode.sum())
            self.stats.draft_proposed += proposed
            self.stats.draft_accepted += accepted
            self.stats.tokens_generated += len(new)
            self.stats.decode_tokens += len(new)
            # one committed cache row per emitted token, same invariant as
            # the chunk loop (rollback already rewound the rejected rows)
            self.kv.slot_len[slot] += len(new)
            cap = self.kv.slot_capacity(slot)
            assert self.kv.slot_len[slot] <= cap, (
                f"slot {slot}: {self.kv.slot_len[slot]} cache rows exceed "
                f"the {cap}-row reservation — speculative rollback drifted")
            if self.flight is not None and (new or proposed):
                # per-window draft accounting next to the tokens it earned
                self.flight.emit("emit", rid=req.rid, slot=slot, tokens=new,
                                 proposed=proposed, accepted=accepted)
            if not active_np[slot]:
                self._finish(req, finished)
                self._release_slot(slot)
        if self.flight is not None:
            self.flight.emit("spec_window", cycles=cycles,
                             proposed=self.stats.draft_proposed - dp0,
                             accepted=self.stats.draft_accepted - da0)
        if tele:
            # per-window acceptance: this harvest's accepted/proposed ratio
            # (a drifting distribution here flags drafter quality decaying
            # over the workload, which the aggregate rate averages away)
            dp = self.stats.draft_proposed - dp0
            if dp:
                self._m_spec_accept.observe(
                    (self.stats.draft_accepted - da0) / dp)

    def step(self) -> list[Request]:
        """One scheduler iteration: admit into free slots (extension
        admissions map their shared pages and start chunking), advance
        every mid-prefill slot by one chunk, run one decode chunk,
        harvest. Returns requests that finished this step.

        With async admission (default under the continuous policy) the
        order double-buffers host work against device decode: groups
        whose prefill overlapped the PREVIOUS chunk commit first (their
        one sync — the prefill long finished), then the decode chunk
        dispatches and `_admit` prepares the NEXT group while it runs.

        Any exception escaping a step triggers the flight recorder's
        crash dump (pool state, block tables, refcounts, in-flight
        requests, event tail) and closes every open trace span, so the
        observability artifacts stay loadable exactly when they matter."""
        try:
            return self._step_inner()
        except Exception as exc:
            if self.flight is not None:
                self.flight.crash_dump(self, exc)
            self.telemetry.tracer.finalize()
            raise

    def _step_inner(self) -> list[Request]:
        finished: list[Request] = []
        if self.async_admission:
            self._commit_admissions(finished)
            self._advance_prefill(finished)
            if self._active_host.any():
                self._decode_and_harvest(finished)  # admits mid-flight
            else:
                # idle pool: nothing to overlap with — admit and commit
                # synchronously so fresh slots decode this very step
                self._admit(finished)
                self._commit_admissions(finished)
                self._decode_and_harvest(finished)
        else:
            self._admit(finished)
            self._advance_prefill(finished)
            self._decode_and_harvest(finished)
        return finished

    def run(self, requests: list[Request], max_steps: int = 1_000_000) -> list[Request]:
        """Drive a workload to completion. `Request.arrival` is the
        scheduler step at which a request reaches the queue (staggered
        arrivals for open-loop workloads)."""
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        done: list[Request] = []
        t = 0
        while pending or self.n_pending:
            while pending and pending[0].arrival <= t:
                self.submit(pending.pop(0))
            done.extend(self.step())
            t += 1
            if t > max_steps:
                raise RuntimeError("scheduler did not converge")
        return done
