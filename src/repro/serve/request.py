"""Request lifecycle model for the serving runtime.

A `Request` moves through QUEUED -> PREFILLING -> DECODING -> FINISHED.
The scheduler owns the transitions; this module only defines the data
model and the per-request / aggregate statistics the runtime reports:
TTFT (submit -> first token), decode tokens/s, and the per-token weight
traffic share (the quantity the HiNM packed format optimises — it shrinks
both with packing and with higher slot occupancy).
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.serve.telemetry.metrics import NAN, Histogram


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"


@dataclasses.dataclass
class SamplingParams:
    max_new_tokens: int = 32
    temperature: float = 0.0     # <= 0 -> greedy
    top_k: int = 0               # 0 -> full vocab
    top_p: float = 0.0           # <= 0 -> disabled (nucleus sampling)
    eos_id: int | None = None    # None -> cfg.eos_id (when in-vocab)
    # per-request RNG seed: the sampled stream depends only on (seed, token
    # index), never on slot assignment or co-residents. None -> rid.
    seed: int | None = None
    # --- speculative decoding (active only on a Scheduler(spec=...)) ---
    # draft tokens this request accepts per verify step: None -> the
    # scheduler's SpecConfig.k, 0 -> speculation off for this request
    # (it still rides the verify batch, one token per step).
    spec_k: int | None = None
    # acceptance rule for stochastic slots: "match" reproduces the exact
    # non-speculative sampled stream (accept a draft token iff it equals
    # the token the per-position key would have drawn); "reject" is
    # classic rejection sampling (unbiased, higher acceptance, different
    # stream). Greedy slots always use exact match.
    spec_accept: str = "match"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                      # (S,) int32
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    embeds: np.ndarray | None = None        # (P, D) modality-frontend stub
    arrival: int = 0                        # scheduler step it becomes visible

    state: RequestState = RequestState.QUEUED
    slot: int | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    finish_reason: str | None = None        # "eos" | "length"

    submit_time: float = 0.0
    admit_time: float = 0.0
    first_token_time: float = 0.0
    finish_time: float = 0.0
    # lifecycle span events (telemetry.SpanEvent), populated when the
    # scheduler serves with telemetry enabled: queued -> prefill[bucket]
    # -> decode -> finish, exportable via TraceRecorder.chrome_trace
    spans: list = dataclasses.field(default_factory=list, repr=False)
    # sum over this request's decode steps of 1/(active slots that step):
    # its share of the whole-model weight reads the batch amortises
    shared_decode_steps: float = 0.0
    # --- prefix sharing + chunked prefill (Scheduler(prefix_share=...,
    # prefill_chunk=...)) ---
    prefix_hit_tokens: int = 0     # prompt rows served from shared pages
    prefill_chunks: int = 0        # extension-prefill dispatches it took
    # cache rows committed so far during a chunked admission (mapped
    # prefix rows + extension-prefilled rows); scheduler-internal cursor
    prefill_cursor: int = 0
    # --- speculative decoding (Scheduler(spec=...)) ---
    spec_verify_steps: int = 0     # verify forwards this request rode
    spec_proposed: int = 0         # draft tokens proposed for it
    spec_accepted: int = 0         # draft tokens accepted (excl. the bonus)

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    @property
    def acceptance_rate(self) -> float:
        """Accepted / proposed draft tokens (0 when never speculated)."""
        return self.spec_accepted / max(self.spec_proposed, 1)

    @property
    def tokens_per_verify_step(self) -> float:
        """Decode tokens emitted per verify forward (> 1 = speculation won)."""
        return (self.n_generated - 1) / max(self.spec_verify_steps, 1)

    @property
    def ttft(self) -> float:
        """Submit -> first token. NaN while no first token exists (queued,
        prefilling, or cancelled requests) — a 0.0 `first_token_time` is
        "never set", and subtracting it would fabricate a huge or negative
        latency instead of an unmistakable sentinel."""
        if not self.first_token_time or not self.submit_time:
            return NAN
        return self.first_token_time - self.submit_time

    @property
    def tokens_per_second(self) -> float:
        """Decode throughput (first token -> finish). NaN until the
        request actually finished (same sentinel rule as `ttft`)."""
        if not self.finish_time or not self.first_token_time:
            return NAN
        span = self.finish_time - self.first_token_time
        return (self.n_generated - 1) / max(span, 1e-9)

    @property
    def tpot(self) -> float:
        """Time per output token after the first (NaN until finished or
        when only the first token was emitted)."""
        if not self.finish_time or not self.first_token_time:
            return NAN
        if self.n_generated <= 1:
            return NAN
        return (self.finish_time - self.first_token_time) / (self.n_generated - 1)

    def weight_bytes_per_token(self, packed_param_bytes: int) -> float:
        """This request's share of packed-weight HBM reads per token."""
        return packed_param_bytes * self.shared_decode_steps / max(self.n_generated, 1)


@dataclasses.dataclass
class ServeStats:
    prefill_seconds: float
    decode_seconds: float
    tokens_generated: int
    packed_param_bytes: int
    dense_param_bytes: int
    requests_finished: int = 0
    finished_at_eos: int = 0
    decode_steps: int = 0          # batched decode steps executed
    # tokens emitted by decode chunks; excludes each request's first token,
    # which is sampled from prefill logits and timed under prefill_seconds
    decode_tokens: int = 0
    # --- speculative decoding: one verify forward = one packed-weight read
    # that can emit up to k+1 tokens per slot ---
    verify_steps: int = 0          # batched verify forwards executed
    lane_verify_steps: int = 0     # sum over slots of verifies they rode
    draft_proposed: int = 0
    draft_accepted: int = 0
    # wall time of the UNFUSED chain's draft dispatches (a slice of
    # decode_seconds, split out so benches don't fold draft dispatch cost
    # into the per-verify step time AND the host gap; the fused scan
    # drafts in-jit, so this stays 0 there)
    spec_draft_seconds: float = 0.0
    # --- prefix sharing + chunked prefill ---
    prefix_hit_tokens: int = 0     # prompt rows served from shared pages
    prefill_rows: int = 0          # prompt rows actually computed by prefill
    prefill_chunks: int = 0        # extension-prefill dispatches executed
    # --- latency distributions (always populated: one observe per request
    # or per decode chunk — the percentile columns in serve_bench do not
    # depend on the telemetry knob) ---
    ttft_hist: Histogram = dataclasses.field(
        default_factory=lambda: Histogram("serve_ttft_seconds"))
    tpot_hist: Histogram = dataclasses.field(
        default_factory=lambda: Histogram("serve_tpot_seconds"))
    step_time_hist: Histogram = dataclasses.field(
        default_factory=lambda: Histogram("serve_decode_step_seconds"))

    def observe_finish(self, req: "Request") -> None:
        """Fold a finished request's latencies into the distributions."""
        if req.ttft == req.ttft:  # NaN-safe: unset timestamps never land
            self.ttft_hist.observe(req.ttft)
        if req.tpot == req.tpot:
            self.tpot_hist.observe(req.tpot)

    def ttft_percentile(self, q: float) -> float:
        return self.ttft_hist.percentile(q)

    def step_time_percentile(self, q: float) -> float:
        return self.step_time_hist.percentile(q)

    @property
    def decode_tokens_per_second(self) -> float:
        return self.decode_tokens / max(self.decode_seconds, 1e-9)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prompt rows served from shared prefix pages instead
        of being recomputed by prefill (0 with sharing off or no hits)."""
        total = self.prefix_hit_tokens + self.prefill_rows
        return self.prefix_hit_tokens / max(total, 1)

    @property
    def acceptance_rate(self) -> float:
        return self.draft_accepted / max(self.draft_proposed, 1)

    @property
    def tokens_per_verify_step(self) -> float:
        """Acceptance-weighted tokens a slot emits per verify it rode
        (1 = no speculation win, k+1 = every draft accepted)."""
        return self.decode_tokens / max(self.lane_verify_steps, 1)

    @property
    def weight_bytes_per_accepted_token(self) -> float:
        """Packed-weight bytes read per decode token under speculation: one
        packed read per verify step, amortised over all tokens it emitted
        (accepted drafts + the bonus/correction token)."""
        return self.packed_param_bytes * self.verify_steps / max(self.decode_tokens, 1)

    @property
    def weight_bytes_ratio(self) -> float:
        return self.packed_param_bytes / max(self.dense_param_bytes, 1)

    @property
    def weight_bytes_per_token(self) -> float:
        """Packed-weight bytes read per decode-emitted token: one full packed
        read per decode step, amortised over the tokens the batch emitted."""
        return self.packed_param_bytes * self.decode_steps / max(self.decode_tokens, 1)
