"""Batched serving engine over HiNM-packed weights.

Serving is where HiNM pays off on TPU (DESIGN.md §2): decode is
weight-bandwidth-bound, and the packed format cuts weight traffic ~4x at
75% sparsity while the vector level also halves matmul FLOPs. The engine:

  - holds packed params (from train.pruning.prune_model) + a dense fallback,
  - prefills a batch of prompts (right-aligned padding-free: prompts are
    length-bucketed by the caller; here we pad to the bucket),
  - decodes greedily / with temperature, batched, with one jit'd step,
  - reports tokens/s and weight-bytes-touched per token (the quantity the
    HiNM kernel optimises).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import PackedHiNM
from repro.models import zoo


@dataclasses.dataclass
class ServeStats:
    prefill_seconds: float
    decode_seconds: float
    tokens_generated: int
    packed_param_bytes: int
    dense_param_bytes: int

    @property
    def decode_tokens_per_second(self) -> float:
        return self.tokens_generated / max(self.decode_seconds, 1e-9)

    @property
    def weight_bytes_ratio(self) -> float:
        return self.packed_param_bytes / max(self.dense_param_bytes, 1)


class ServeEngine:
    def __init__(self, cfg, params, max_seq: int = 512, temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.temperature = temperature
        self._decode = jax.jit(
            lambda p, t, c: zoo.decode_step(p, cfg, t, c), donate_argnums=(2,)
        )
        self._prefill = jax.jit(
            lambda p, t, c, e: (
                lambda out: (zoo.logits_fn(p, cfg, out[0]), out[1])
            )(zoo.prefill(p, cfg, t, c, embeds=e)),
            static_argnames=(),
        )

    def packed_bytes(self) -> tuple[int, int]:
        packed = dense = 0
        for leaf in jax.tree.leaves(
            self.params, is_leaf=lambda x: isinstance(x, PackedHiNM)
        ):
            if isinstance(leaf, PackedHiNM):
                packed += leaf.packed_bytes()
                dense += leaf.dense_bytes()
            else:
                b = leaf.size * jnp.dtype(leaf.dtype).itemsize
                packed += b
                dense += b
        return packed, dense

    def generate(
        self,
        prompts: np.ndarray,          # (B, S_prompt) int32
        max_new_tokens: int = 32,
        embeds: np.ndarray | None = None,
        rng_seed: int = 0,
    ) -> tuple[np.ndarray, ServeStats]:
        b, s = prompts.shape
        cache = zoo.make_cache(self.cfg, b, self.max_seq)
        t0 = time.time()
        logits, cache = self._prefill(self.params, jnp.asarray(prompts), cache,
                                      None if embeds is None else jnp.asarray(embeds))
        jax.block_until_ready(logits)
        t1 = time.time()

        key = jax.random.PRNGKey(rng_seed)
        out = np.zeros((b, max_new_tokens), dtype=np.int32)
        tok = self._sample(logits, key)
        out[:, 0] = np.asarray(tok)[:, 0]
        for i in range(1, max_new_tokens):
            logits, cache = self._decode(self.params, tok, cache)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
            out[:, i] = np.asarray(tok)[:, 0]
        jax.block_until_ready(tok)
        t2 = time.time()
        pb, db = self.packed_bytes()
        return out, ServeStats(
            prefill_seconds=t1 - t0,
            decode_seconds=t2 - t1,
            tokens_generated=b * max_new_tokens,
            packed_param_bytes=pb,
            dense_param_bytes=db,
        )

    def _sample(self, logits, key):
        logits = logits[:, : self.cfg.vocab]
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        probs = jax.nn.softmax(logits / self.temperature, axis=-1)
        return jax.random.categorical(key, jnp.log(probs + 1e-9), axis=-1)[
            :, None
        ].astype(jnp.int32)
