"""Fixed-batch compat facade over the continuous-batching scheduler.

`ServeEngine.generate` keeps the original synchronous API — one batch of
equal-length prompts in, a (B, max_new_tokens) token matrix out — but now
runs on `serve.Scheduler`: every prompt becomes a `Request`, the batch
becomes a slot pool of width B, and decode runs device-resident in
chunked `lax.scan` steps instead of a per-token host loop. New code
should drive `Scheduler` directly (staggered arrivals, mixed sampling
params, slot reuse); this wrapper exists so existing callers and the
paper benchmarks keep working unchanged.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.request import Request, SamplingParams, ServeStats
from repro.serve.scheduler import Scheduler, param_bytes

__all__ = ["ServeEngine", "ServeStats"]


class ServeEngine:
    def __init__(self, cfg, params, max_seq: int = 512, temperature: float = 0.0,
                 top_k: int = 0, top_p: float = 0.0, decode_chunk: int = 8,
                 page: int | None = 64, n_pages: int | str | None = "auto",
                 mesh=None, spec=None, packed: bool | str = "auto",
                 telemetry=None, prefix_share: bool | str = "auto",
                 prefill_chunk: int | None = None):
        self.cfg = cfg
        self.params = params
        self.packed = packed
        self.max_seq = max_seq
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.decode_chunk = decode_chunk
        self.page = page
        self.n_pages = n_pages
        self.mesh = mesh
        self.spec = spec
        self.telemetry = telemetry
        self.prefix_share = prefix_share
        self.prefill_chunk = prefill_chunk
        self._sched: Scheduler | None = None

    def packed_bytes(self) -> tuple[int, int]:
        return param_bytes(self.params)

    def _scheduler(self, batch: int, rng_seed: int) -> Scheduler:
        if self._sched is None or self._sched.max_slots != batch:
            self._sched = Scheduler(
                self.cfg, self.params, max_slots=batch, max_seq=self.max_seq,
                decode_chunk=self.decode_chunk, rng_seed=rng_seed,
                page=self.page, n_pages=self.n_pages, mesh=self.mesh,
                spec=self.spec, packed=self.packed, telemetry=self.telemetry,
                prefix_share=self.prefix_share,
                prefill_chunk=self.prefill_chunk)
        else:
            self._sched.reset(rng_seed)
        return self._sched

    def generate(
        self,
        prompts: np.ndarray,          # (B, S_prompt) int32
        max_new_tokens: int = 32,
        embeds: np.ndarray | None = None,
        rng_seed: int = 0,
    ) -> tuple[np.ndarray, ServeStats]:
        b = prompts.shape[0]
        sched = self._scheduler(b, rng_seed)
        reqs = [
            Request(
                rid=i,
                prompt=np.asarray(prompts[i], np.int32),
                params=SamplingParams(max_new_tokens=max_new_tokens,
                                      temperature=self.temperature,
                                      top_k=self.top_k, top_p=self.top_p),
                embeds=None if embeds is None else np.asarray(embeds[i]),
            )
            for i in range(b)
        ]
        sched.run(reqs)
        # EOS-terminated rows are zero-padded to the fixed output width
        out = np.zeros((b, max_new_tokens), dtype=np.int32)
        for r in reqs:
            out[r.rid, : r.n_generated] = r.tokens
        return out, dataclasses.replace(sched.stats)
