"""Deterministic replay of a recorded serving run.

A flight record fully determines the admission schedule: every request's
prompt, sampling parameters, seed and arrival step are captured in its
`submit` event, and everything downstream — bucket choices, group
boundaries, chunk boundaries, page draws, spec accept counts — is a pure
function of that schedule plus the scheduler configuration (the `config`
event).  `replay(record, scheduler)` rebuilds the workload from the
record, drives a fresh recording scheduler over it, and compares the new
event stream and token streams against the original, event for event and
token for token.

The caller constructs the replay scheduler (params cannot ride in a
JSON record); `requests_from_record` rebuilds the workload; the config
fingerprints are part of the event streams, so a mismatched scheduler
surfaces as the very first diverging event rather than a deep token
mystery.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.flightrec.diff import DiffReport, diff_records
from repro.serve.flightrec.events import as_events
from repro.serve.request import Request, SamplingParams


def requests_from_record(record) -> list[Request]:
    """Rebuild the workload a record captured: one fresh `Request` per
    `submit` event, carrying the identical prompt, sampling parameters
    and arrival step.  Embeds requests are not replayable (the modality
    tensors do not ride in a JSON record) and raise."""
    reqs = []
    for ev in as_events(record):
        if ev.kind != "submit":
            continue
        d = ev.data
        if d.get("embeds"):
            raise ValueError(
                f"request {d['rid']}: embeds requests cannot be rebuilt "
                "from a flight record (modality tensors are not recorded)")
        params = SamplingParams(
            max_new_tokens=d["max_new"], temperature=d["temperature"],
            top_k=d["top_k"], top_p=d["top_p"], eos_id=d["eos"],
            seed=d["seed"], spec_k=d["spec_k"], spec_accept=d["spec_accept"])
        reqs.append(Request(rid=d["rid"],
                            prompt=np.asarray(d["prompt"], np.int32),
                            params=params, arrival=d["arrival"]))
    return reqs


@dataclasses.dataclass
class ReplayReport:
    events_equal: bool
    tokens_equal: bool
    n_events: int                  # events in the reference record
    n_requests: int
    diff: DiffReport               # event-stream triage (first divergence)
    token_mismatches: list[tuple]  # (rid, recorded, replayed)

    @property
    def ok(self) -> bool:
        return self.events_equal and self.tokens_equal

    def render(self) -> str:
        lines = [f"replay: {self.n_requests} requests, "
                 f"{self.n_events} reference events"]
        lines.append(f"tokens: {'identical' if self.tokens_equal else f'{len(self.token_mismatches)} request(s) diverged'}")
        for rid, rec, got in self.token_mismatches[:5]:
            lines.append(f"  rid {rid}: recorded {rec} != replayed {got}")
        lines.append("events: " + ("identical" if self.events_equal
                                   else self.diff.first.describe()))
        return "\n".join(lines)

    def assert_equal(self) -> None:
        if not self.ok:
            raise AssertionError("replay diverged from record\n"
                                 + self.render())


def recorded_tokens(record) -> dict[int, list[int]]:
    """Per-request final token streams, from the record's `finish`
    events."""
    return {ev.data["rid"]: list(ev.data["tokens"])
            for ev in as_events(record) if ev.kind == "finish"}


def replay(record, scheduler, max_steps: int = 1_000_000) -> ReplayReport:
    """Re-execute a recorded run on `scheduler` (a freshly constructed
    scheduler with recording ON and the same configuration) and compare
    the replayed event and token streams against the record."""
    if getattr(scheduler, "flight", None) is None:
        raise ValueError("replay needs a recording scheduler — construct "
                         "it with flightrec=True")
    if any(ev.kind not in ("dispatch", "config")
           for ev in scheduler.flight.events):
        raise ValueError("replay needs a fresh scheduler: this one already "
                         "recorded workload events")
    ref = as_events(record)
    reqs = requests_from_record(ref)
    scheduler.run(reqs, max_steps=max_steps)
    if scheduler.flight.dropped or len(ref) > scheduler.flight.capacity:
        raise ValueError(
            "replay recorder overflowed its ring buffer "
            f"(capacity {scheduler.flight.capacity}); raise "
            "FlightRecorder(capacity=...) above the record length")
    diff = diff_records(ref, scheduler.flight.events)
    want = recorded_tokens(ref)
    mismatches = [(r.rid, want.get(r.rid), r.tokens) for r in reqs
                  if r.tokens != want.get(r.rid)]
    return ReplayReport(
        events_equal=diff.equal, tokens_equal=not mismatches,
        n_events=len(ref), n_requests=len(reqs), diff=diff,
        token_mismatches=mismatches)
