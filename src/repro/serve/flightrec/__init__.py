"""Serving flight recorder: structured decision log, deterministic
replay, and first-divergence triage.

Layered on (not replacing) the telemetry registry: metrics aggregate,
the flight recorder *attributes* — every admission, page, prefix, spec
and kernel-dispatch decision becomes a typed, causally-keyed event that
can be exported (JSON lines), replayed (`replay`), and diffed against
another run (`diff_records`) down to the first diverging decision.

Off by default (`Scheduler(flightrec=...)`); see `events.py` for the
recorder, `replay.py` for deterministic re-execution, `diff.py` for
triage.
"""
from repro.serve.flightrec.diff import DiffReport, Divergence, diff_records
from repro.serve.flightrec.events import (FlightEvent, FlightRecorder,
                                          as_events, load_jsonl,
                                          resolve_flightrec)
from repro.serve.flightrec.replay import (ReplayReport, recorded_tokens,
                                          replay, requests_from_record)

__all__ = [
    "DiffReport",
    "Divergence",
    "FlightEvent",
    "FlightRecorder",
    "ReplayReport",
    "as_events",
    "diff_records",
    "load_jsonl",
    "recorded_tokens",
    "replay",
    "requests_from_record",
    "resolve_flightrec",
]
