"""Flight recorder: a bounded ring buffer of typed scheduler decisions.

Every host-side decision the serving stack makes — admission grouping,
prefix CoW mapping, page ref/deref, LRU eviction, spec accept counts,
fused-scan dispatch, async prepare/commit pairing — lands here as one
`FlightEvent`: a monotonically-sequenced `(seq, kind, data)` record whose
payload carries the causal ids (`rid`, `slot`, `pages`) that tie it to a
request's lifecycle.  Wall-clock time is recorded (`t`) but deliberately
EXCLUDED from event identity: two runs of the same workload on the same
scheduler configuration must produce byte-identical `(kind, data)`
streams, which is what makes a record a deterministic replay script
(`flightrec.replay`) and a diffable conformance artifact
(`flightrec.diff_records`).

The buffer is bounded (`capacity` events, default 64k): in a long-lived
server the recorder keeps the most recent window and counts what it
dropped (`dropped`), so the crash dump always has the tail that led up to
the failure.  `dump()`/`load_jsonl()` round-trip the stream through JSON
lines; `crash_dump()` snapshots the pool's host-side truth — free lists,
page refcounts, block tables, slot lengths, in-flight requests — next to
the event tail when the scheduler dies mid-step.

Chrome-trace bridging: constructed with a `TraceRecorder`, every emit
also lands as an instant event on a `flightrec` track, so decisions line
up against the span timeline in Perfetto.  The scheduler wires the bridge
only when telemetry is enabled; a bare recorder stays trace-free.
"""
from __future__ import annotations

import collections
import json
import time


class FlightEvent:
    """One recorded decision. `t` (perf_counter seconds) is diagnostic
    only — `signature()` is the identity replay and diff compare on."""

    __slots__ = ("seq", "kind", "t", "data")

    def __init__(self, seq: int, kind: str, t: float, data: dict):
        self.seq = seq
        self.kind = kind
        self.t = t
        self.data = data

    def signature(self) -> tuple:
        return (self.kind, _canon(self.data))

    def stream_key(self) -> tuple:
        """Causal-stream id: events about one request align under its
        `rid`; pool events with no request attribution align under their
        `slot`; everything else shares the global stream."""
        if "rid" in self.data:
            return ("rid", self.data["rid"])
        if "slot" in self.data:
            return ("slot", self.data["slot"])
        return ("global",)

    def to_dict(self) -> dict:
        return {"seq": self.seq, "kind": self.kind, "t": self.t, **self.data}

    @classmethod
    def from_dict(cls, d: dict) -> "FlightEvent":
        d = dict(d)
        return cls(d.pop("seq"), d.pop("kind"), d.pop("t", 0.0), d)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        body = ", ".join(f"{k}={v!r}" for k, v in self.data.items())
        return f"FlightEvent#{self.seq} {self.kind}({body})"


def _canon(v):
    """Hashable, order-stable form of a payload (lists -> tuples)."""
    if isinstance(v, dict):
        return tuple(sorted((k, _canon(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_canon(x) for x in v)
    return v


class FlightRecorder:
    def __init__(self, capacity: int = 65536, tracer=None):
        if capacity < 1:
            raise ValueError("FlightRecorder capacity must be >= 1")
        self.capacity = capacity
        self._buf: collections.deque[FlightEvent] = collections.deque(
            maxlen=capacity)
        self.seq = 0          # total events emitted (dropped ones included)
        self.tracer = tracer  # TraceRecorder bridge (instant events), or None
        self.crash: dict | None = None   # last crash_dump() snapshot
        self.crash_path: str | None = None

    # -- recording --------------------------------------------------------

    def emit(self, kind: str, **data) -> FlightEvent:
        ev = FlightEvent(self.seq, kind, time.perf_counter(), data)
        self.seq += 1
        self._buf.append(ev)
        if self.tracer is not None:
            self.tracer.instant("flightrec", kind, ev.t, **data)
        return ev

    @property
    def dropped(self) -> int:
        """Events pushed out of the ring by capacity pressure."""
        return self.seq - len(self._buf)

    @property
    def events(self) -> list[FlightEvent]:
        return list(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    def clear(self) -> None:
        self._buf.clear()
        self.seq = 0
        self.crash = None

    # -- export -----------------------------------------------------------

    def dump(self, path: str) -> None:
        """JSON-lines export: one event per line, in sequence order."""
        with open(path, "w") as f:
            for ev in self._buf:
                f.write(json.dumps(ev.to_dict()) + "\n")

    # -- crash dump -------------------------------------------------------

    def crash_dump(self, scheduler, exc: BaseException | None = None,
                   tail: int = 256) -> dict:
        """Snapshot the scheduler's host-side truth at the moment of
        death: the exception, every in-flight request, the pool's free
        lists / page refcounts / block tables, and the event tail that
        led here.  Stored on `self.crash`; written to `self.crash_path`
        (JSON) when one is set.  Never raises — a crash dump that crashes
        would mask the original failure."""
        try:
            snap = {
                "error": repr(exc) if exc is not None else None,
                "decode_steps": scheduler.stats.decode_steps,
                "requests": _requests_snapshot(scheduler),
                "pool": _pool_snapshot(scheduler.kv),
                "draft_pool": (_pool_snapshot(scheduler.draft_kv)
                               if scheduler.draft_kv is not None else None),
                "pending_admits": [
                    [r.rid for r in rec[0]]
                    for rec in scheduler._pending_admits],
                "prefix_index_pages": (scheduler.prefix.n_pages
                                       if scheduler.prefix is not None
                                       else None),
                "events_dropped": self.dropped,
                "events_tail": [ev.to_dict()
                                for ev in list(self._buf)[-tail:]],
            }
        except Exception as dump_exc:  # pragma: no cover - defensive
            snap = {"error": repr(exc) if exc is not None else None,
                    "dump_error": repr(dump_exc)}
        self.crash = snap
        if self.crash_path:
            try:
                with open(self.crash_path, "w") as f:
                    json.dump(snap, f, indent=1)
            except OSError:  # pragma: no cover - defensive
                pass
        return snap


def _requests_snapshot(scheduler) -> list[dict]:
    reqs = []
    seen = set()
    sources = (
        [("queued", r) for r in scheduler._queue]
        + [("prefilling", r) for r in scheduler._prefilling.values()]
        + [("decoding", r) for r in scheduler._running.values()]
        + [("pending_commit", r) for rec in scheduler._pending_admits
           for r in rec[0]])
    for phase, r in sources:
        if id(r) in seen:
            continue
        seen.add(id(r))
        reqs.append({"rid": r.rid, "phase": phase, "slot": r.slot,
                     "n_prompt": len(r.prompt), "n_tokens": len(r.tokens),
                     "prefill_cursor": r.prefill_cursor,
                     "max_new": r.params.max_new_tokens})
    return reqs


def _pool_snapshot(kv) -> dict:
    snap = {"n_slots": kv.n_slots, "free_slots": list(kv._free),
            "slot_len": [int(x) for x in kv.slot_len],
            "slot_cap": [int(x) for x in kv._slot_cap],
            "paged": kv.paged}
    if kv.paged:
        snap.update({
            "n_pages": kv.n_pages,
            "free_pages": [list(d) for d in kv._free_pages],
            "page_ref": [int(x) for x in kv._page_ref],
            "block_tables": {str(s): list(p)
                             for s, p in sorted(kv._slot_pages.items())},
            "n_free_pages": kv.n_free_pages,
            "n_referenced_pages": kv.n_referenced_pages,
            "n_shared_pages": kv.n_shared_pages,
            "cow_copies": kv.cow_copies,
        })
    return snap


def load_jsonl(path: str) -> list[FlightEvent]:
    """Load a `FlightRecorder.dump()` JSON-lines record."""
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(FlightEvent.from_dict(json.loads(line)))
    return events


def as_events(record) -> list[FlightEvent]:
    """Coerce a record argument — recorder, event list, or JSONL path —
    to a plain event list."""
    if isinstance(record, FlightRecorder):
        return record.events
    if isinstance(record, str):
        return load_jsonl(record)
    return list(record)


def resolve_flightrec(arg, tracer=None) -> FlightRecorder | None:
    """Resolve `Scheduler(flightrec=...)`: None/False -> off (the default
    — recording costs a dict build per decision), True -> a fresh
    recorder, an instance -> itself (shared across schedulers if the
    caller wants one merged stream)."""
    if isinstance(arg, FlightRecorder):
        return arg
    if arg:
        return FlightRecorder(tracer=tracer)
    return None
