"""First-divergence triage between two flight records.

`diff_records(a, b)` answers the question the conformance suite could
not: two runs disagreed — *which decision diverged first?*  Events are
partitioned into causal streams (per-`rid`, per-`slot`, global) so that
interleave differences from scheduling noise don't mask the real
divergence: within each stream events are compared pairwise in order,
and the divergence with the lowest sequence number across all streams is
reported with surrounding context from both records.

Comparison uses `FlightEvent.signature()` — kind plus payload, wall
clock excluded — so identical decisions made at different speeds
compare equal, and the first *decision* difference (a different backend
resolution, a different admission group, a different accept count) is
what surfaces.
"""
from __future__ import annotations

import dataclasses

from repro.serve.flightrec.events import FlightEvent, as_events


@dataclasses.dataclass
class Divergence:
    """One stream's first disagreement. `a`/`b` is None when that record's
    stream ended early (a missing event is itself the divergence)."""
    stream: tuple
    index: int                     # position within the stream
    a: FlightEvent | None
    b: FlightEvent | None
    context_a: list[FlightEvent]   # events preceding the divergence (a)
    context_b: list[FlightEvent]

    @property
    def seq(self) -> int:
        """Global order of this divergence (min of the two records')."""
        seqs = [ev.seq for ev in (self.a, self.b) if ev is not None]
        return min(seqs) if seqs else 0

    def describe(self) -> str:
        def fmt(ev):
            if ev is None:
                return "<stream ended>"
            body = ", ".join(f"{k}={v!r}" for k, v in ev.data.items())
            return f"{ev.kind}({body}) [seq {ev.seq}]"

        key = "/".join(str(p) for p in self.stream)
        return (f"stream {key} event #{self.index}:\n"
                f"  a: {fmt(self.a)}\n"
                f"  b: {fmt(self.b)}")


@dataclasses.dataclass
class DiffReport:
    equal: bool
    n_a: int
    n_b: int
    n_streams: int
    first: Divergence | None       # lowest-seq divergence, None when equal
    divergences: list[Divergence]  # one per diverging stream, seq order

    def render(self) -> str:
        """Human-readable triage report (the conformance artifact body)."""
        lines = [f"flight-record diff: {self.n_a} vs {self.n_b} events, "
                 f"{self.n_streams} causal streams"]
        if self.equal:
            lines.append("records are event-for-event identical")
            return "\n".join(lines)
        lines.append(f"{len(self.divergences)} diverging stream(s); "
                     f"first divergence:")
        lines.append(self.first.describe())
        if self.first.context_a or self.first.context_b:
            lines.append("context (a):")
            for ev in self.first.context_a:
                lines.append(f"  {ev!r}")
            lines.append("context (b):")
            for ev in self.first.context_b:
                lines.append(f"  {ev!r}")
        others = [d for d in self.divergences if d is not self.first]
        if others:
            lines.append("other diverging streams:")
            for d in others:
                lines.append("  " + d.describe().replace("\n", "\n  "))
        return "\n".join(lines)


def _streams(events: list[FlightEvent]) -> dict[tuple, list[FlightEvent]]:
    out: dict[tuple, list[FlightEvent]] = {}
    for ev in events:
        out.setdefault(ev.stream_key(), []).append(ev)
    return out


def diff_records(a, b, context: int = 5) -> DiffReport:
    """Align two records by causal stream and report the first diverging
    event of each stream that disagrees.  `a`/`b` accept a
    `FlightRecorder`, an event list, or a JSONL path."""
    ea, eb = as_events(a), as_events(b)
    sa, sb = _streams(ea), _streams(eb)
    divergences: list[Divergence] = []
    for key in list(sa) + [k for k in sb if k not in sa]:
        la, lb = sa.get(key, []), sb.get(key, [])
        idx = None
        for i in range(min(len(la), len(lb))):
            if la[i].signature() != lb[i].signature():
                idx = i
                break
        if idx is None:
            if len(la) == len(lb):
                continue
            idx = min(len(la), len(lb))  # one stream ended early
        divergences.append(Divergence(
            stream=key, index=idx,
            a=la[idx] if idx < len(la) else None,
            b=lb[idx] if idx < len(lb) else None,
            context_a=la[max(0, idx - context):idx],
            context_b=lb[max(0, idx - context):idx]))
    divergences.sort(key=lambda d: d.seq)
    return DiffReport(
        equal=not divergences, n_a=len(ea), n_b=len(eb),
        n_streams=len(set(sa) | set(sb)),
        first=divergences[0] if divergences else None,
        divergences=divergences)
