from repro.serve import sampler
from repro.serve.engine import ServeEngine
from repro.serve.flightrec import (FlightEvent, FlightRecorder, diff_records,
                                   load_jsonl, replay, resolve_flightrec)
from repro.serve.kv import SlotKVCache
from repro.serve.prefix import PrefixIndex, PrefixMatch
from repro.serve.request import Request, RequestState, SamplingParams, ServeStats
from repro.serve.scheduler import Scheduler, param_bytes
from repro.serve.spec import ModelDrafter, NgramDrafter, SpecConfig
from repro.serve.telemetry import (MetricsRegistry, Telemetry, TraceRecorder,
                                   resolve_telemetry)

__all__ = [
    "sampler",
    "FlightEvent",
    "FlightRecorder",
    "MetricsRegistry",
    "Telemetry",
    "TraceRecorder",
    "resolve_telemetry",
    "ModelDrafter",
    "NgramDrafter",
    "PrefixIndex",
    "PrefixMatch",
    "Request",
    "RequestState",
    "SamplingParams",
    "Scheduler",
    "ServeEngine",
    "ServeStats",
    "SlotKVCache",
    "SpecConfig",
    "diff_records",
    "load_jsonl",
    "param_bytes",
    "replay",
    "resolve_flightrec",
]
