from repro.serve import sampler
from repro.serve.engine import ServeEngine
from repro.serve.kv import SlotKVCache
from repro.serve.request import Request, RequestState, SamplingParams, ServeStats
from repro.serve.scheduler import Scheduler, param_bytes

__all__ = [
    "sampler",
    "Request",
    "RequestState",
    "SamplingParams",
    "Scheduler",
    "ServeEngine",
    "ServeStats",
    "SlotKVCache",
    "param_bytes",
]
