"""Host-side radix index over token prefixes -> refcounted physical pages.

The index is a trie at **page granularity**: each node represents one full
page of tokens (a tuple of ``page`` ids) extending its parent's prefix,
and carries the physical page id whose K/V rows hold exactly those tokens
at exactly those positions.  Pure-attention caches make that sound — a
K/V row is a per-(token, position) projection, so identical prefixes at
identical positions cache bitwise-identical rows (``zoo.supports_prefix_share``
gates the families where that holds).

Ownership model (the kpos-ownership split, see serve.kv):

  * every node holds **one reference** on its page for as long as it is
    indexed — a page can outlive every slot that wrote or mapped it
    (retention), which is what makes a later request hit;
  * ``match`` walks full-page children and returns the shared chain plus
    the best divergent tail (longest common prefix within the next page)
    for copy-on-write;
  * ``register`` indexes a freshly prefilled slot's full prompt pages
    (only pages every row of which is prompt — decode rows never share);
  * ``evict`` drops least-recently-used leaves whose page is referenced by
    the index alone, unwinding chains bottom-up until enough pages return
    to the free list.  Nodes whose page a live slot still maps are never
    worth evicting (dropping them frees nothing).

The index never touches device memory: it tracks page *ids*; the pool's
refcounts (``SlotKVCache.ref_pages`` / ``deref_pages``) decide when a
page's kpos rows are actually swept back to the sentinel.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class PrefixMatch:
    """Result of a trie walk for one prompt."""
    page_ids: list[int]            # full shared pages, prefix order
    shared_rows: int               # full-page rows (len(page_ids) * page)
    cow_src: int | None = None     # divergent tail page to copy, if any
    cow_rows: int = 0              # rows of cow_src that match the prompt

    @property
    def total_rows(self) -> int:
        return self.shared_rows + self.cow_rows


class _Node:
    __slots__ = ("block", "page_id", "parent", "children", "last_used")

    def __init__(self, block, page_id, parent):
        self.block = block          # the page-sized token tuple this adds
        self.page_id = page_id
        self.parent = parent
        self.children: dict[tuple, _Node] = {}
        self.last_used = 0


class PrefixIndex:
    def __init__(self, page: int, flight=None):
        self.page = page
        self._root = _Node((), -1, None)
        self._clock = 0
        self.n_pages = 0            # nodes (= index-referenced pages)
        self.evictions = 0          # pages dropped under free-list pressure
        # flight recorder (serve/flightrec): trie match lengths, retention
        # registrations and evict/shield decisions as typed events
        self._flight = flight

    def _emit(self, kind: str, **data) -> None:
        if self._flight is not None:
            self._flight.emit(kind, **data)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # -- lookup --------------------------------------------------------------

    def match(self, tokens, max_rows: int) -> PrefixMatch:
        """Longest indexed prefix of ``tokens``, capped at ``max_rows``
        (admission always prefills >= 1 row: the first sampled token needs
        logits, so the cap is prompt_len - 1).  Full-page hits walk the
        trie; the first divergence point may additionally yield a
        copy-on-write tail — the child page sharing the longest common
        prefix within the next page of tokens."""
        page = self.page
        node, ids, i = self._root, [], 0
        while i + page <= len(tokens) and (i + page) <= max_rows:
            child = node.children.get(tuple(int(t) for t in tokens[i:i + page]))
            if child is None:
                break
            child.last_used = self._tick()
            ids.append(child.page_id)
            node = child
            i += page
        cow_src, cow_rows, donor = None, 0, None
        tail = tuple(int(t) for t in tokens[i:i + page])
        if tail:
            for child in node.children.values():
                j = 0
                while (j < len(tail) and j < len(child.block)
                       and child.block[j] == tail[j]):
                    j += 1
                j = min(j, max_rows - i)
                if j > cow_rows:
                    cow_src, cow_rows, donor = child.page_id, j, child
            if donor is not None:
                # touching the donor keeps a hot divergence point resident
                donor.last_used = self._tick()
        if ids or cow_rows:
            # trie walk outcome: how many full pages / CoW rows this
            # prompt can reuse (misses stay silent — they dominate cold
            # workloads and carry no decision)
            self._emit("prefix_match", pages=len(ids), rows=i,
                       cow_rows=cow_rows)
        return PrefixMatch(ids, i, cow_src, cow_rows)

    # -- registration --------------------------------------------------------

    def register(self, tokens, page_ids, kv) -> int:
        """Index the full-page chain of ``tokens``: logical page p of the
        prompt is backed by physical ``page_ids[p]``.  Each NEW node takes
        one refcount on its page (`kv.ref_pages`) — the retention reference
        that lets the page outlive its writing slot.  Pages already indexed
        under the same chain (a duplicate prompt) are just touched; their
        physical twin stays owned by the slot alone.  Returns the number of
        pages newly indexed."""
        page = self.page
        node, new = self._root, 0
        new_ids = []
        for p in range(len(tokens) // page):
            block = tuple(int(t) for t in tokens[p * page:(p + 1) * page])
            child = node.children.get(block)
            if child is None:
                child = _Node(block, int(page_ids[p]), node)
                node.children[block] = child
                kv.ref_pages([child.page_id])
                self.n_pages += 1
                new += 1
                new_ids.append(child.page_id)
            child.last_used = self._tick()
            node = child
        if new:
            # retention refs taken: these pages now outlive their slot
            self._emit("prefix_register", pages=new_ids, total=self.n_pages)
        return new

    # -- eviction ------------------------------------------------------------

    def _leaves(self):
        stack, out = list(self._root.children.values()), []
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    def _drop(self, node: _Node, kv) -> int:
        del node.parent.children[node.block]
        self.n_pages -= 1
        self.evictions += 1
        return kv.deref_pages([node.page_id])

    def evict(self, kv, n_pages: int, protect=()) -> int:
        """Free up to ``n_pages`` pages back to ``kv``'s free list by
        dropping LRU leaves whose page only the index references (dropping
        a page a live slot still maps frees nothing, so those stay).
        Chains unwind bottom-up: an inner node becomes a leaf once its
        children go.  ``protect`` lists pages a pending admission matched
        but has not yet mapped — evicting one would free it while the
        admission still points at it, and the free list could hand the
        same page back as that very slot's private page.  Returns pages
        actually freed."""
        protect = set(protect)
        freed = 0
        dropped: list[int] = []
        shielded: list[int] = []
        while freed < n_pages:
            leaves = self._leaves()
            cands = [n for n in leaves
                     if kv.page_ref(n.page_id) == 1
                     and n.page_id not in protect]
            if not cands:
                # leaves that WOULD have been evictable but for the shield
                shielded = sorted(n.page_id for n in leaves
                                  if kv.page_ref(n.page_id) == 1
                                  and n.page_id in protect)
                break
            victim = min(cands, key=lambda n: n.last_used)
            dropped.append(victim.page_id)
            freed += self._drop(victim, kv)
        self._emit("prefix_evict", need=n_pages, freed=freed,
                   dropped=dropped, shielded=shielded)
        return freed

    def clear(self, kv) -> int:
        """Drop every node (deref all retention references).  Pages no slot
        maps return to the free list immediately; shared ones follow when
        their last slot releases.  Returns pages freed now."""
        freed = 0
        while self._root.children:
            for leaf in self._leaves():
                freed += self._drop(leaf, kv)
        return freed
