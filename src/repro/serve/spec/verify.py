"""Acceptance logic for the multi-token verify step.

One verify forward scores ``S = k + 1`` input tokens per slot — the
pending token followed by k draft candidates — producing ``logits[:, i]``
= the target model's distribution for the token AFTER input i.  From
those distributions `acceptance` decides, fully vectorized per slot:

* **greedy slots** (temperature <= 0): accept the leading run of drafts
  matching the argmax chain, then emit the argmax at the first mismatch
  (the "correction") or after a full run (the "bonus").  Because an
  accepted draft IS the argmax of its prefix, the emitted tokens are
  exactly the non-speculative greedy stream.

* **stochastic slots, "match"** (default): identical scheme, but the
  per-position target token is the one `sampler.sample` draws with the
  slot's per-position key (`sampler.fold_keys`) — i.e. the exact token
  the non-speculative loop would have sampled at that stream index, so
  spec decode is token-identical even under temperature/top-k/top-p.
  Acceptance = P(draft guesses the sampled token).

* **stochastic slots, "reject"**: classic speculative rejection sampling
  against the delta proposal of a greedy drafter — accept draft d_i with
  probability p_i(d_i); on the first rejection draw the replacement from
  p_i masked at d_i (the residual of p - delta_d), after a full run draw
  the bonus from p_K.  Unbiased (each emitted token is distributed
  exactly as non-speculative sampling) with strictly higher acceptance
  than "match", but a different stream.

Emission is then capped by the slot's remaining token budget and cut at
the first EOS; the count doubles as the cache-row ``keep`` for
`SlotKVCache.rollback` (every emitted token has exactly one committed
row: the pending token's row plus one per accepted draft — the newest
emitted token's row is, as everywhere in this runtime, not yet written).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.serve import sampler


def position_keys(base_key, seeds: jax.Array, gens: jax.Array, s: int):
    """(B, S) draw keys: key[b, i] is exactly the key the non-speculative
    loop uses for slot b's token index gens[b] + i."""
    def row(seed, g0):
        kb = jax.random.fold_in(base_key, seed)
        return jax.vmap(lambda i: jax.random.fold_in(kb, g0 + i))(
            jnp.arange(s, dtype=jnp.int32))

    return jax.vmap(row)(seeds, gens)


def acceptance(logits, drafts, tok, *, base_key, seeds, gens, temp, topk,
               topp, eos, rem, active, k_eff, match, stochastic: bool,
               any_reject: bool = True):
    """Vectorized accept/emit for one verify step.

    logits (B, S, V) f32; drafts (B, S-1) int32; tok (B, 1) pending token.
    Per-slot vectors: temp/topp f32, topk/eos/rem/gens/seeds/k_eff int32,
    active/match bool.  `stochastic` is the usual static all-greedy
    specialization flag; `any_reject` statically elides the rejection-
    sampling pipeline (probs, uniform and residual draws) when every
    stochastic lane uses the default "match" rule — there its outputs
    would all be discarded by the use_match select.  Returns (emits
    (B, S) int32 with -1 padding, cnt (B,) emitted == cache rows kept,
    judged (B,) drafts whose verdict reached the stream (the
    acceptance-rate denominator), tok', active', rem', gens')."""
    b, s, v = logits.shape
    k = s - 1
    ar = jnp.arange(s, dtype=jnp.int32)

    g_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)    # (B, S)
    use_reject = stochastic and any_reject
    if stochastic:
        keys = position_keys(base_key, seeds, gens, s)       # (B, S) keys
        kflat = lambda ks, m: ks.reshape((m,) + ks.shape[2:])  # noqa: E731
        flat = lambda a: jnp.repeat(a, s)                    # noqa: E731
        lg_flat = logits.reshape(b * s, v)
        keys_flat = kflat(keys, b * s)
        samp = sampler.sample(keys_flat, lg_flat, flat(temp), flat(topk),
                              flat(topp)).reshape(b, s)
        tgt = jnp.where((temp > 0)[:, None], samp, g_tok)
    else:
        tgt = g_tok
    if use_reject:
        # rejection sampling against the drafter's delta proposal
        t = jnp.maximum(temp, 1e-6)
        masked = sampler.mask_logits(
            lg_flat / flat(t)[:, None], flat(topk), flat(topp)).reshape(b, s, v)
        probs = jax.nn.softmax(masked, axis=-1)
        p_draft = jnp.take_along_axis(
            probs[:, :k], drafts[..., None], axis=-1)[..., 0]  # (B, k)
        def fold_tag(ks, tag):
            return jax.vmap(lambda kk: jax.random.fold_in(kk, tag))(ks)

        u = jax.vmap(jax.random.uniform)(
            fold_tag(keys_flat, 1)).reshape(b, s)[:, :k]
        rs_accept = u < p_draft
        # residual draw: p with the rejected draft removed (delta proposal)
        res_logits = jnp.where(
            jax.nn.one_hot(drafts, v, dtype=bool), -jnp.inf, masked[:, :k])
        res = jax.vmap(jax.random.categorical)(
            fold_tag(kflat(keys[:, :k], b * k), 2),
            res_logits.reshape(b * k, v)).astype(jnp.int32).reshape(b, k)
    else:
        rs_accept = jnp.zeros((b, k), bool)
        res = jnp.zeros((b, k), jnp.int32)

    use_match = match | (temp <= 0)
    hit = jnp.where(use_match[:, None], drafts == tgt[:, :k], rs_accept)
    hit &= ar[None, :k] < k_eff[:, None]       # per-request draft-len cap
    n_acc = jnp.cumprod(hit.astype(jnp.int32), axis=1).sum(axis=1)  # (B,)

    # token emitted at position i: accepted draft (i < n), else the
    # correction/bonus (i == n): match mode -> the target token; reject
    # mode -> residual draw (mismatch) or plain sample (full run)
    corr = tgt
    if use_reject:
        corr_rej = jnp.concatenate([res, tgt[:, k:]], axis=1)
        corr = jnp.where(use_match[:, None], tgt, corr_rej)
    pad_drafts = jnp.concatenate(
        [drafts, jnp.zeros((b, 1), jnp.int32)], axis=1)
    emits0 = jnp.where(ar[None, :] < n_acc[:, None], pad_drafts,
                       jnp.where(ar[None, :] == n_acc[:, None], corr, -1))

    cnt = jnp.minimum(n_acc + 1, rem)
    is_eos = (eos[:, None] >= 0) & (emits0 == eos[:, None]) & (
        ar[None, :] < cnt[:, None])
    first_eos = jnp.argmax(is_eos, axis=1).astype(jnp.int32)
    cnt = jnp.where(is_eos.any(axis=1), jnp.minimum(cnt, first_eos + 1), cnt)
    # CONTRACT: inactive lanes emit and keep NOTHING.  `cnt` doubles as
    # the per-lane cache-row `keep` for the rollback that follows every
    # verify (host-side `SlotKVCache.rollback` in the unfused chain, the
    # in-scan `zoo.cache_rollback` in the fused loop) — zeroing it here is
    # what rewinds free lanes' junk rows AND shields mid-chunked-prefill
    # slots' committed prefix from the verify's speculative writes, and
    # (via `append_history`, which appends `cnt` tokens) what keeps their
    # n-gram history clean of half-prefilled junk.  The spec x chunked-
    # prefill x prefix-share conformance mode pins this.
    cnt = jnp.where(active, cnt, 0)

    emits = jnp.where(ar[None, :] < cnt[:, None], emits0, -1)
    last = jnp.take_along_axis(
        emits0, jnp.maximum(cnt - 1, 0)[:, None], axis=1)[:, 0]
    hit_eos = is_eos.any(axis=1) & active
    rem2 = rem - cnt
    active2 = active & ~hit_eos & (rem2 > 0)
    tok2 = jnp.where(active2, last, tok[:, 0])[:, None]
    gens2 = gens + cnt
    # judged draft count for the acceptance-rate stats: the cnt-1 accepted
    # drafts that reached the stream, plus the one draft whose REJECTION
    # reached it (its correction was the emitted token: cnt ran to
    # n_acc+1 with the run stopped by a mismatch, not by the k_eff cap).
    # Drafts beyond an EOS or budget cut were never judgeable in the true
    # stream and are not counted against the drafter.
    judged = jnp.maximum(cnt - 1, 0) + (
        (cnt == n_acc + 1) & (n_acc < k_eff)).astype(jnp.int32)
    judged = jnp.where(cnt > 0, judged, 0)
    return emits, cnt, judged, tok2, active2, rem2, gens2
