"""Draft-token proposal sources for speculative decoding.

A drafter guesses the next ``k`` tokens of every active slot; the verify
step then scores all guesses with ONE packed-weight read (`spec/verify`).
Two implementations:

``NgramDrafter`` — host-free self-speculative prompt lookup.  The
scheduler keeps a device-resident per-slot token history (prompt +
emitted tokens); `ngram_propose` finds the most recent earlier occurrence
of the trailing n-gram in that history and proposes the tokens that
followed it.  No extra model, no extra weight traffic: acceptance is high
exactly when the output re-walks its own context (templated/repetitive
prompts, code infilling, summaries quoting the source).

``ModelDrafter`` — a paired small model (e.g. qwen2_0_5b drafting for
qwen2_5_14b, declared as ``ArchConfig.draft_arch`` and resolved via
`from_zoo`).  The scheduler runs it autoregressively for ``k + 1`` greedy
steps per cycle in its own stripe `SlotKVCache`; the extra step writes
the last draft's own KV row so the draft cache tracks the target cache
row-for-row and the SAME accept count rolls both back (`serve/kv.py`).
Costs draft-model weight reads and prefill; wins when the draft actually
predicts the target (trained pairs), loses to the free n-gram drafter
when it cannot (see serve/README.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def seed_history(prompt, first_token: int, max_seq: int):
    """(history row, length) arming a slot's n-gram corpus at admission.

    The row holds the request's COMPLETE prompt followed by its first
    sampled token — including prompt rows that prefix-shared admission
    mapped by page reference and never prefilled.  Seeding from anything
    less (e.g. only the rows the extension path computed) would silently
    strip the shared prefix from the lookup corpus and collapse ngram
    acceptance on exactly the repetitive shared-prefix workloads
    speculation targets; `tests/serve_conformance.py` pins the acceptance
    parity between shared and unshared admission."""
    row = np.zeros((max_seq,), np.int32)
    plen = min(len(prompt), max_seq - 1)
    row[:plen] = prompt[:plen]
    row[plen] = first_token
    return row, plen + 1


def ngram_propose(hist: jax.Array, hlen: jax.Array, tok: jax.Array,
                  k: int, n: int = 2) -> jax.Array:
    """Prompt-lookup proposals.  hist (B, H) int32 token history (prompt +
    emitted, the pending token last); hlen (B,) valid rows; tok (B, 1) the
    pending token (== hist[hlen-1]).  Finds the latest j < hlen - n with
    ``hist[j:j+n] == hist[hlen-n:hlen]`` and proposes
    ``hist[j+n : j+n+k]``; positions with no match (or past the history)
    fall back to repeating the pending token — a cheap guess the verify
    step simply rejects."""
    b, h = hist.shape
    ar = jnp.arange(h, dtype=jnp.int32)
    # trailing n-gram per slot (clamped reads are masked by the hlen check)
    gram = jnp.stack([
        jnp.take_along_axis(
            hist, jnp.clip(hlen - n + i, 0, h - 1)[:, None], axis=1)[:, 0]
        for i in range(n)], axis=1)                          # (B, n)
    ok = jnp.ones((b, h - n + 1), bool)
    for i in range(n):
        ok &= hist[:, i: h - n + 1 + i] == gram[:, i][:, None]
    j_ar = jnp.arange(h - n + 1, dtype=jnp.int32)
    cand = jnp.where(ok & (j_ar[None, :] < (hlen - n)[:, None]), j_ar[None, :], -1)
    jbest = jnp.max(cand, axis=1)                            # (B,) -1 = none
    start = jbest + n
    idx = start[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    guess = jnp.take_along_axis(hist, jnp.clip(idx, 0, h - 1), axis=1)
    usable = (jbest[:, None] >= 0) & (idx < hlen[:, None])
    return jnp.where(usable, guess, tok).astype(jnp.int32)


def append_history(hist: jax.Array, hlen: jax.Array, emits: jax.Array,
                   cnt: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Append each slot's ``cnt`` emitted tokens (``emits (B, S)``, -1 pad)
    to its history.  Writes past the buffer are dropped (the buffer is
    sized for prompt + max_new, so that only pads)."""
    b, s = emits.shape
    h = hist.shape[1]
    ar = jnp.arange(s, dtype=jnp.int32)
    idx = hlen[:, None] + ar[None, :]
    bidx = jnp.arange(b)[:, None]
    live = ar[None, :] < cnt[:, None]
    cur = hist[bidx, jnp.clip(idx, 0, h - 1)]
    new = jnp.where(live, emits, cur)
    hist = hist.at[bidx, jnp.clip(idx, 0, h - 1)].set(new)
    return hist, hlen + cnt


class Drafter:
    """Interface: `kind` tags how the scheduler wires proposals."""

    kind = ""


class NgramDrafter(Drafter):
    """Self-speculative prompt-lookup drafter (no draft model)."""

    kind = "ngram"

    def __init__(self, n: int = 2):
        if n < 1:
            raise ValueError("n-gram order must be >= 1")
        self.n = n


class ModelDrafter(Drafter):
    """Paired small draft model with its own stripe KV pool."""

    kind = "model"

    def __init__(self, cfg, params):
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"draft model family {cfg.family!r} is not supported: the "
                "drafter decodes plain token prompts (no embeds frontend)")
        self.cfg = cfg
        self.params = params

    @classmethod
    def from_zoo(cls, target_cfg, rng_seed: int = 0, reduced: dict | None = None):
        """Resolve ``target_cfg.draft_arch`` via configs and init params.
        ``reduced`` overrides shrink the draft to match a `.reduced()`
        target (vocabularies must line up: drafts are ids the target
        scores).  Params are randomly initialised — plug checkpointed
        weights in via the constructor for a real deployment."""
        from repro.configs.base import load_arch
        from repro.models import zoo

        arch = getattr(target_cfg, "draft_arch", "")
        if not arch:
            raise ValueError(
                f"{target_cfg.name}: no draft_arch pairing declared")
        cfg = load_arch(arch)
        if reduced is not None:
            cfg = cfg.reduced(**reduced)
        if cfg.vocab > target_cfg.vocab:
            raise ValueError(
                f"draft vocab {cfg.vocab} exceeds target vocab "
                f"{target_cfg.vocab}: drafts would be unscorable ids")
        params = zoo.init(jax.random.PRNGKey(rng_seed), cfg)
        return cls(cfg, params)
