"""Speculative decoding for the serving runtime (`Scheduler(spec=...)`).

Decode is weight-bandwidth-bound: every non-speculative step reads the
whole packed model to emit ONE token per slot.  Speculation flips the
ratio — a drafter guesses ``k`` tokens per slot (`drafter.py`), one
multi-token verify forward scores all of them against the target model
(`verify.py` + `zoo.verify_step`), and the paged slot pool commits the
accepted prefix while rolling the rejected suffix back
(`serve.kv.SlotKVCache.rollback`).  Each verify is one packed-weight
read that can emit up to ``k + 1`` tokens per slot, so the HiNM packed
format's bytes-per-token win multiplies by the acceptance-weighted
tokens-per-verify — without changing a single emitted token (greedy and
"match"-mode stochastic decode are token-identical to the
non-speculative stream; `tests/serve_conformance.py` pins it across
family x layout x sharding).
"""
from __future__ import annotations

import dataclasses

from repro.serve.spec.drafter import (Drafter, ModelDrafter, NgramDrafter,
                                      append_history, ngram_propose,
                                      seed_history)
from repro.serve.spec.verify import acceptance, position_keys

__all__ = [
    "Drafter",
    "ModelDrafter",
    "NgramDrafter",
    "SpecConfig",
    "acceptance",
    "append_history",
    "ngram_propose",
    "position_keys",
    "seed_history",
]


@dataclasses.dataclass
class SpecConfig:
    """Pool-level speculative-decoding configuration.

    ``k`` — draft tokens per verify step (verify width is k + 1); requests
    can lower their own cap via `SamplingParams.spec_k` (0 = off for that
    request; it still rides the verify batch at one token per step).
    ``drafter`` — "ngram" (host-free prompt lookup), "model" (resolve the
    target's `draft_arch` pairing with random init), or a `Drafter`
    instance (the way to supply real draft weights or a reduced config).
    ``ngram`` — lookup n-gram order for the ngram drafter.
    ``fused`` — run the whole draft -> verify -> accept -> rollback ->
    history cycle as one device-resident `lax.scan` body (one jit dispatch
    and one host sync per scheduler step, like the non-speculative chunk
    loop); False falls back to the per-cycle dispatch chain (one draft jit,
    one verify jit and one rollback dispatch per cycle) — the debugging
    knob, token-identical by contract.
    ``cycles`` — draft/verify cycles per scheduler step.  None derives a
    default from the loop shape: the fused scan runs ``decode_chunk``
    cycles per dispatch (each cycle emits >= 1 token per active lane, so a
    chunk of C cycles covers at least what the non-spec chunk emits); the
    unfused chain keeps about one non-speculative chunk's worth,
    max(1, decode_chunk // (k + 1)), because every extra cycle there costs
    a full dispatch round-trip.
    """

    k: int = 4
    drafter: object = "ngram"
    ngram: int = 2
    fused: bool = True
    cycles: int | None = None
