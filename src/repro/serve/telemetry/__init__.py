"""Serving observability: metrics registry + request-lifecycle tracing.

`Telemetry` is the bundle the scheduler threads through the runtime — a
`MetricsRegistry` (counters / gauges / log-bucketed histograms, see
`metrics.py`) plus a `TraceRecorder` (Chrome-trace span export, see
`tracing.py`) behind one `enabled` switch:

- **disabled (the default)** — the registry still exists and trace-time
  instruments (prefill compile counts, kernel dispatch decisions) still
  record, because they cost nothing per decode step; but all hot-path
  wall-clock instrumentation and span recording is skipped, so serving
  runs at baseline speed (<1% decode tokens/s, asserted by the bench);
- **enabled** — admission/prefill/decode/host-gap/spec phases are timed
  into histograms, the KV pool's occupancy gauges update, and every
  request accumulates lifecycle spans exported as Perfetto-loadable
  trace JSON.  Budget: <3% decode tokens/s at bench shapes (CI-asserted
  by `benchmarks/serve_bench.py`).

Resolution order for `Scheduler(telemetry=...)`: a `Telemetry` instance
is used as-is; `True`/`False` build a fresh enabled/disabled bundle;
`None`/"auto" defer to `perf_knobs.KNOBS.telemetry` (off by default).
"""
from __future__ import annotations

from repro.serve.telemetry.metrics import (GLOBAL, Counter, Gauge, Histogram,
                                           MetricsRegistry, reset_global)
from repro.serve.telemetry.tracing import (InstantEvent, SpanEvent,
                                           TraceRecorder)

__all__ = [
    "GLOBAL",
    "Counter",
    "Gauge",
    "Histogram",
    "InstantEvent",
    "MetricsRegistry",
    "SpanEvent",
    "Telemetry",
    "TraceRecorder",
    "reset_global",
    "resolve_telemetry",
]


class Telemetry:
    def __init__(self, enabled: bool = True, annotate: bool = False,
                 registry: MetricsRegistry | None = None,
                 tracer: TraceRecorder | None = None):
        self.enabled = bool(enabled)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else TraceRecorder(annotate)

    def annotation(self, name: str, step: int | None = None):
        return self.tracer.annotation(name, step)

    def snapshot(self, include_global: bool = True) -> dict:
        """JSON-able snapshot of this bundle's registry, with the
        process-global instruments (kernel dispatch counters) merged in
        under their own key so the two scopes stay distinguishable."""
        snap = {"enabled": self.enabled, **self.registry.snapshot()}
        if include_global:
            snap["global"] = GLOBAL.snapshot()
        return snap

    def dump_metrics(self, path: str) -> None:
        import json

        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)

    def dump_trace(self, path: str) -> None:
        self.tracer.dump(path)


def resolve_telemetry(arg) -> Telemetry:
    """Resolve the `Scheduler(telemetry=...)` knob to a `Telemetry`."""
    if isinstance(arg, Telemetry):
        return arg
    if arg is None or arg == "auto":
        from repro.perf_knobs import KNOBS

        return Telemetry(enabled=bool(KNOBS.telemetry))
    return Telemetry(enabled=bool(arg))
