"""Request-lifecycle tracing: typed spans -> Chrome trace-event JSON.

The scheduler records wall-clock spans on named tracks — one `scheduler`
track for batched phases (admission prefill, decode chunks, spec
draft/verify dispatch) and one `req<rid>` track per request for its
lifecycle (queued -> prefill[bucket] -> decode -> finish).  Request spans
are additionally accumulated on `Request.spans` as typed `SpanEvent`s so
tests and callers can introspect a lifecycle without parsing the export.

`chrome_trace()` emits the Trace Event Format (B/E duration pairs plus
thread-name metadata) that `chrome://tracing` and Perfetto open directly:
every track becomes a named thread, timestamps are microseconds relative
to the recorder epoch, and events are sorted so B/E pairs nest correctly.

The optional jax-profiler bridge (`annotation(...)`) wraps host phases in
`jax.profiler.TraceAnnotation` (and decode chunks in
`StepTraceAnnotation`) so the same span names line up with device traces
when a jax profile is being captured; it is a no-op when the profiler is
absent or the bridge is off.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time


@dataclasses.dataclass
class SpanEvent:
    """One closed host span: [t0, t1] in perf_counter seconds."""

    name: str
    t0: float
    t1: float
    track: str = "scheduler"
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class TraceRecorder:
    def __init__(self, annotate: bool = False):
        self.epoch = time.perf_counter()
        self.events: list[SpanEvent] = []
        self.annotate = annotate
        self._tids: dict[str, int] = {}

    def span(self, track: str, name: str, t0: float, t1: float,
             **args) -> SpanEvent:
        ev = SpanEvent(name, t0, t1, track=track, args=args)
        self.events.append(ev)
        return ev

    def request_span(self, req, name: str, t0: float, t1: float,
                     **args) -> SpanEvent:
        """Record a lifecycle span on the request's own track AND on the
        request object itself (`Request.spans`)."""
        ev = self.span(f"req{req.rid}", name, t0, t1, rid=req.rid, **args)
        req.spans.append(ev)
        return ev

    @contextlib.contextmanager
    def timed(self, track: str, name: str, **args):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.span(track, name, t0, time.perf_counter(), **args)

    def annotation(self, name: str, step: int | None = None):
        """jax-profiler bridge: a TraceAnnotation (StepTraceAnnotation when
        `step` is given) context when the bridge is on, else a null
        context.  Host spans then share names with device-trace slices."""
        if not self.annotate:
            return contextlib.nullcontext()
        try:
            from jax import profiler
        except ImportError:  # pragma: no cover - jax is a hard dep here
            return contextlib.nullcontext()
        if step is not None and hasattr(profiler, "StepTraceAnnotation"):
            return profiler.StepTraceAnnotation(name, step_num=step)
        return profiler.TraceAnnotation(name)

    # -- export ----------------------------------------------------------

    def _tid(self, track: str) -> int:
        return self._tids.setdefault(track, len(self._tids) + 1)

    def chrome_trace(self) -> dict:
        """Chrome Trace Event Format dict (Perfetto-loadable).

        B/E pairs per span, microsecond timestamps relative to the
        recorder epoch, one named thread per track.  Events are sorted by
        (ts, E-before-B) so back-to-back spans whose edges share a
        timestamp still nest; negative-duration spans are clamped to
        zero-width rather than emitting an unmatched pair.
        """
        raw = []
        for ev in self.events:
            tid = self._tid(ev.track)
            ts0 = max(0.0, (ev.t0 - self.epoch) * 1e6)
            ts1 = max(ts0, (ev.t1 - self.epoch) * 1e6)
            args = {k: v for k, v in ev.args.items()}
            raw.append({"name": ev.name, "cat": "serve", "ph": "B",
                        "ts": ts0, "pid": 0, "tid": tid, "args": args})
            raw.append({"name": ev.name, "cat": "serve", "ph": "E",
                        "ts": ts1, "pid": 0, "tid": tid})
        raw.sort(key=lambda e: (e["ts"], 0 if e["ph"] == "E" else 1))
        meta = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                 "args": {"name": "repro.serve"}}]
        for track, tid in sorted(self._tids.items(), key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M", "pid": 0,
                         "tid": tid, "args": {"name": track}})
        return {"traceEvents": meta + raw, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
