"""Request-lifecycle tracing: typed spans -> Chrome trace-event JSON.

The scheduler records wall-clock spans on named tracks — one `scheduler`
track for batched phases (admission prefill, decode chunks, spec
draft/verify dispatch) and one `req<rid>` track per request for its
lifecycle (queued -> prefill[bucket] -> decode -> finish).  Request spans
are additionally accumulated on `Request.spans` as typed `SpanEvent`s so
tests and callers can introspect a lifecycle without parsing the export.

`chrome_trace()` emits the Trace Event Format (B/E duration pairs plus
thread-name metadata) that `chrome://tracing` and Perfetto open directly:
every track becomes a named thread, timestamps are microseconds relative
to the recorder epoch, and events are sorted so B/E pairs nest correctly.

The optional jax-profiler bridge (`annotation(...)`) wraps host phases in
`jax.profiler.TraceAnnotation` (and decode chunks in
`StepTraceAnnotation`) so the same span names line up with device traces
when a jax profile is being captured; it is a no-op when the profiler is
absent or the bridge is off.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time


@dataclasses.dataclass
class SpanEvent:
    """One host span: [t0, t1] in perf_counter seconds.  `t1 is None`
    marks a span still open (`TraceRecorder.begin`); export auto-closes
    open spans so an abandoned request or a mid-step exception can never
    leave an unmatched "B" event in the Chrome trace."""

    name: str
    t0: float
    t1: float | None
    track: str = "scheduler"
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def open(self) -> bool:
        return self.t1 is None

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0


@dataclasses.dataclass
class InstantEvent:
    """One zero-duration marker (Chrome trace "i" phase) — the flight
    recorder's per-decision bridge onto the span timeline."""

    name: str
    t: float
    track: str = "flightrec"
    args: dict = dataclasses.field(default_factory=dict)


class TraceRecorder:
    def __init__(self, annotate: bool = False):
        self.epoch = time.perf_counter()
        self.events: list[SpanEvent] = []
        self.instants: list[InstantEvent] = []
        self.annotate = annotate
        self._tids: dict[str, int] = {}

    def span(self, track: str, name: str, t0: float, t1: float,
             **args) -> SpanEvent:
        ev = SpanEvent(name, t0, t1, track=track, args=args)
        self.events.append(ev)
        return ev

    def begin(self, track: str, name: str, t0: float | None = None,
              **args) -> SpanEvent:
        """Open a span now; close it later with `end` (or let export /
        `finalize` close it).  For lifecycles that may never reach their
        natural end — a request abandoned mid-decode, a scheduler that
        raises — so the trace stays structurally valid either way."""
        ev = SpanEvent(name, time.perf_counter() if t0 is None else t0,
                       None, track=track, args=args)
        self.events.append(ev)
        return ev

    def end(self, ev: SpanEvent, t1: float | None = None, **args) -> SpanEvent:
        ev.t1 = time.perf_counter() if t1 is None else t1
        ev.args.update(args)
        return ev

    def finalize(self, t: float | None = None) -> int:
        """Close every open span (at `t`, default now). Returns how many
        were open — the scheduler calls this from its exception path so a
        crash leaves a loadable trace, and export calls it implicitly."""
        t = time.perf_counter() if t is None else t
        n = 0
        for ev in self.events:
            if ev.t1 is None:
                ev.t1 = max(ev.t0, t)
                ev.args.setdefault("auto_closed", True)
                n += 1
        return n

    def instant(self, track: str, name: str, t: float | None = None,
                **args) -> InstantEvent:
        ev = InstantEvent(name, time.perf_counter() if t is None else t,
                          track=track, args=args)
        self.instants.append(ev)
        return ev

    def request_span(self, req, name: str, t0: float, t1: float,
                     **args) -> SpanEvent:
        """Record a lifecycle span on the request's own track AND on the
        request object itself (`Request.spans`)."""
        ev = self.span(f"req{req.rid}", name, t0, t1, rid=req.rid, **args)
        req.spans.append(ev)
        return ev

    @contextlib.contextmanager
    def timed(self, track: str, name: str, **args):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.span(track, name, t0, time.perf_counter(), **args)

    def annotation(self, name: str, step: int | None = None):
        """jax-profiler bridge: a TraceAnnotation (StepTraceAnnotation when
        `step` is given) context when the bridge is on, else a null
        context.  Host spans then share names with device-trace slices."""
        if not self.annotate:
            return contextlib.nullcontext()
        try:
            from jax import profiler
        except ImportError:  # pragma: no cover - jax is a hard dep here
            return contextlib.nullcontext()
        if step is not None and hasattr(profiler, "StepTraceAnnotation"):
            return profiler.StepTraceAnnotation(name, step_num=step)
        return profiler.TraceAnnotation(name)

    # -- export ----------------------------------------------------------

    def _tid(self, track: str) -> int:
        return self._tids.setdefault(track, len(self._tids) + 1)

    def chrome_trace(self) -> dict:
        """Chrome Trace Event Format dict (Perfetto-loadable).

        B/E pairs per span, microsecond timestamps relative to the
        recorder epoch, one named thread per track.  Events are sorted by
        (ts, E-before-B) so back-to-back spans whose edges share a
        timestamp still nest; negative-duration spans are clamped to
        zero-width rather than emitting an unmatched pair, and spans still
        open at export (`begin` without `end`) are auto-closed first —
        the trace parses even when a request was abandoned mid-decode.
        Flight-recorder instants ride along as "i" events.
        """
        self.finalize()
        raw = []
        for ev in self.events:
            tid = self._tid(ev.track)
            ts0 = max(0.0, (ev.t0 - self.epoch) * 1e6)
            # a zero-width pair would sort its E before its own B under
            # the E-before-B tiebreak below; 1ns of width keeps the pair
            # matched (auto-closed spans clamp to their open time)
            ts1 = max(ts0 + 1e-3, (ev.t1 - self.epoch) * 1e6)
            args = {k: v for k, v in ev.args.items()}
            raw.append({"name": ev.name, "cat": "serve", "ph": "B",
                        "ts": ts0, "pid": 0, "tid": tid, "args": args})
            raw.append({"name": ev.name, "cat": "serve", "ph": "E",
                        "ts": ts1, "pid": 0, "tid": tid})
        for iv in self.instants:
            raw.append({"name": iv.name, "cat": "flightrec", "ph": "i",
                        "ts": max(0.0, (iv.t - self.epoch) * 1e6), "pid": 0,
                        "tid": self._tid(iv.track), "s": "t",
                        "args": _json_args(iv.args)})
        raw.sort(key=lambda e: (e["ts"], 0 if e["ph"] == "E" else 1))
        meta = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                 "args": {"name": "repro.serve"}}]
        for track, tid in sorted(self._tids.items(), key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M", "pid": 0,
                         "tid": tid, "args": {"name": track}})
        return {"traceEvents": meta + raw, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


def _json_args(args: dict) -> dict:
    """Instant-event args must survive json.dump (flight payloads carry
    numpy scalars occasionally); anything exotic falls back to str."""
    def f(v):
        if isinstance(v, (list, tuple)):
            return [f(x) for x in v]
        if isinstance(v, (bool, int, float, str)) or v is None:
            return v
        try:
            return v.item()  # numpy scalar
        except AttributeError:
            return str(v)

    return {k: f(v) for k, v in args.items()}
