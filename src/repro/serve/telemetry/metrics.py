"""Low-overhead serving metrics: counters, gauges, log-bucketed histograms.

The registry is the serving runtime's single sink for numeric
observability: the scheduler times admission/prefill/decode/host-gap
phases into histograms, the KV pool tracks page/slot occupancy through
gauges, and trace-time events (prefill compiles, paged-attention backend
dispatch) land in labeled counters.  Everything snapshots to plain
JSON-able dicts (`MetricsRegistry.snapshot` / `from_snapshot` round-trip
exactly) and renders Prometheus text exposition for scraping.

Design constraints, in order:

- **recording must be cheap** — an `observe()` on the decode hot path is
  a float compare, an int bump and (while under the sample cap) a list
  append; no locks, no allocation of label dicts per call.  Callers hold
  the instrument object, not the registry, so the per-step cost never
  includes a name lookup;
- **percentiles must be trustworthy** — a histogram keeps its raw
  samples up to ``sample_cap`` (serving runs at bench scale stay far
  under it), so p50/p90/p99 are *exact* (numpy-identical) until the cap,
  and only then degrade to log-bucket interpolation whose error is
  bounded by the bucket's geometric width;
- **instruments are single-process** — the serving loop is
  single-threaded host code; there is deliberately no locking.
"""
from __future__ import annotations

import json
import math

import numpy as np

NAN = float("nan")


def _label_key(labels: dict | None) -> tuple:
    return () if not labels else tuple(sorted(labels.items()))


class Counter:
    """Monotonic event count (floats allowed for weighted counts)."""

    kind = "counter"

    def __init__(self, name: str, labels: dict | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"name": self.name, "kind": self.kind,
                "labels": self.labels, "value": self.value}

    def _restore(self, snap: dict) -> None:
        self.value = snap["value"]


class Gauge:
    """Point-in-time level with high/low-water tracking (`min`/`max`
    observed since creation — the pool's free-page low-water mark is
    `gauge.min` of the free-page gauge, no extra bookkeeping)."""

    kind = "gauge"

    def __init__(self, name: str, labels: dict | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self.value = 0.0
        self.min = NAN
        self.max = NAN

    def set(self, v: float) -> None:
        self.value = v
        if not v >= self.min:   # NaN-safe: first set seeds both marks
            self.min = v
        if not v <= self.max:
            self.max = v

    def inc(self, n: float = 1) -> None:
        self.set(self.value + n)

    def dec(self, n: float = 1) -> None:
        self.set(self.value - n)

    def snapshot(self) -> dict:
        return {"name": self.name, "kind": self.kind, "labels": self.labels,
                "value": self.value, "min": self.min, "max": self.max}

    def _restore(self, snap: dict) -> None:
        self.value = snap["value"]
        self.min = snap["min"]
        self.max = snap["max"]


class Histogram:
    """Log-bucketed distribution with exact-percentile extraction.

    Buckets are geometric: upper bounds ``lo * growth**i`` for
    ``i in [0, n_buckets)`` plus a final +inf overflow bucket; values
    ``<= lo`` land in bucket 0.  The defaults (1 microsecond .. ~4000 s
    at growth 2) cover every latency this runtime can produce.

    Raw samples are retained up to ``sample_cap`` so ``percentile`` is
    numpy-exact for bench/test-scale runs; past the cap it falls back to
    geometric interpolation inside the covering bucket (error bounded by
    the bucket width, clamped to the observed [min, max]).
    """

    kind = "histogram"

    def __init__(self, name: str, labels: dict | None = None, *,
                 lo: float = 1e-6, growth: float = 2.0,
                 n_buckets: int = 40, sample_cap: int = 8192):
        if lo <= 0 or growth <= 1 or n_buckets < 1:
            raise ValueError("need lo > 0, growth > 1, n_buckets >= 1")
        self.name = name
        self.labels = dict(labels or {})
        self.lo = float(lo)
        self.growth = float(growth)
        self.n_buckets = int(n_buckets)
        self.sample_cap = int(sample_cap)
        self.counts = [0] * (self.n_buckets + 1)  # + overflow
        self.count = 0
        self.sum = 0.0
        self.min = NAN
        self.max = NAN
        self._samples: list[float] = []

    # -- recording --------------------------------------------------------

    def observe(self, v: float, n: int = 1) -> None:
        """Record `v` (`n` identical observations in one call — e.g. a
        decode chunk's per-step mean observed once per scanned step)."""
        v = float(v)
        self.counts[self._bucket(v)] += n
        self.count += n
        self.sum += v * n
        if not v >= self.min:
            self.min = v
        if not v <= self.max:
            self.max = v
        if len(self._samples) < self.sample_cap:
            self._samples.extend([v] * min(n, self.sample_cap - len(self._samples)))

    def _bucket(self, v: float) -> int:
        if v <= self.lo:
            return 0
        i = int(math.ceil(math.log(v / self.lo) / math.log(self.growth)))
        return min(i, self.n_buckets)

    def bucket_bounds(self, i: int) -> tuple[float, float]:
        """(lower, upper] value range of bucket `i` (upper inf for the
        overflow bucket, lower 0 for the underflow bucket)."""
        up = math.inf if i >= self.n_buckets else self.lo * self.growth ** i
        down = 0.0 if i == 0 else self.lo * self.growth ** (i - 1)
        return down, up

    # -- extraction -------------------------------------------------------

    @property
    def exact(self) -> bool:
        """True while every observation is still individually retained."""
        return self.count == len(self._samples)

    def percentile(self, q: float) -> float:
        """q-th percentile (0..100). Exact (numpy-identical, linear
        interpolation) while under the sample cap; log-bucket estimate
        beyond it. NaN for an empty histogram."""
        if self.count == 0:
            return NAN
        if self.exact:
            return float(np.percentile(self._samples, q))
        rank = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank and c:
                down, up = self.bucket_bounds(i)
                if not math.isfinite(up):
                    return self.max
                frac = 1.0 - (cum - rank) / c
                down = max(down, self.lo / self.growth)
                est = down * (up / down) ** frac  # geometric interpolation
                return float(min(max(est, self.min), self.max))
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else NAN

    def percentiles(self, qs=(50, 90, 99)) -> dict:
        return {f"p{q:g}": self.percentile(q) for q in qs}

    def snapshot(self) -> dict:
        return {"name": self.name, "kind": self.kind, "labels": self.labels,
                "lo": self.lo, "growth": self.growth,
                "n_buckets": self.n_buckets, "sample_cap": self.sample_cap,
                "counts": list(self.counts), "count": self.count,
                "sum": self.sum, "min": self.min, "max": self.max,
                "samples": list(self._samples)}

    def _restore(self, snap: dict) -> None:
        self.counts = list(snap["counts"])
        self.count = snap["count"]
        self.sum = snap["sum"]
        self.min = snap["min"]
        self.max = snap["max"]
        self._samples = list(snap["samples"])


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Instrument store keyed by (name, sorted labels).

    Repeated registration with the same key returns the existing
    instrument, so call sites need no get-or-create dance.  A name maps
    to exactly one instrument kind across all label sets (mixed kinds
    under one name would be un-renderable in Prometheus exposition).
    """

    def __init__(self):
        self._metrics: dict[tuple, object] = {}
        self._kinds: dict[str, str] = {}

    def _get(self, cls, name: str, labels: dict | None, **kw):
        if self._kinds.setdefault(name, cls.kind) != cls.kind:
            raise ValueError(
                f"metric {name!r} already registered as "
                f"{self._kinds[name]}, not {cls.kind}")
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = cls(name, labels, **kw)
        return m

    def counter(self, name: str, labels: dict | None = None) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, labels: dict | None = None) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, labels: dict | None = None,
                  **kw) -> Histogram:
        return self._get(Histogram, name, labels, **kw)

    def __iter__(self):
        return iter(self._metrics.values())

    def __len__(self) -> int:
        return len(self._metrics)

    def get(self, name: str, labels: dict | None = None):
        """Existing instrument or None (no registration side effect)."""
        return self._metrics.get((name, _label_key(labels)))

    def value(self, name: str, labels: dict | None = None, default=None):
        m = self.get(name, labels)
        return default if m is None else getattr(m, "value", default)

    # -- snapshot / exposition -------------------------------------------

    def snapshot(self) -> dict:
        """Plain-dict snapshot (JSON-able; NaNs mapped to None)."""
        return {"metrics": [_json_safe(m.snapshot()) for m in self]}

    def to_json(self, **kw) -> str:
        return json.dumps(self.snapshot(), **kw)

    @classmethod
    def from_snapshot(cls, snap: dict | str) -> "MetricsRegistry":
        """Rebuild a registry from `snapshot()` output (or its JSON)."""
        if isinstance(snap, str):
            snap = json.loads(snap)
        reg = cls()
        for m in snap["metrics"]:
            m = _nan_safe(m)
            mcls = _KINDS[m["kind"]]
            kw = {}
            if m["kind"] == "histogram":
                kw = {k: m[k] for k in ("lo", "growth", "n_buckets",
                                        "sample_cap")}
            inst = reg._get(mcls, m["name"], m["labels"], **kw)
            inst._restore(m)
        return reg

    def render_prometheus(self) -> str:
        """Prometheus text exposition (histograms as cumulative buckets)."""
        by_name: dict[str, list] = {}
        for m in self:
            by_name.setdefault(m.name, []).append(m)
        out = []
        for name, ms in sorted(by_name.items()):
            out.append(f"# TYPE {name} {ms[0].kind}")
            for m in ms:
                if m.kind == "histogram":
                    cum = 0
                    for i, c in enumerate(m.counts):
                        cum += c
                        _, up = m.bucket_bounds(i)
                        le = "+Inf" if not math.isfinite(up) else repr(up)
                        out.append(f"{name}_bucket"
                                   f"{_prom_labels(m.labels, le=le)} {cum}")
                    out.append(f"{name}_sum{_prom_labels(m.labels)} {m.sum}")
                    out.append(f"{name}_count{_prom_labels(m.labels)} {m.count}")
                else:
                    out.append(f"{name}{_prom_labels(m.labels)} {m.value}")
        return "\n".join(out) + "\n"


def _prom_labels(labels: dict, **extra) -> str:
    items = {**labels, **extra}
    if not items:
        return ""
    body = ",".join(f'{k}="{_prom_escape(v)}"' for k, v in sorted(items.items()))
    return "{" + body + "}"


def _prom_escape(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _json_safe(d: dict) -> dict:
    """NaN -> None so snapshots survive strict JSON parsers."""
    def f(v):
        if isinstance(v, float) and math.isnan(v):
            return None
        if isinstance(v, list):
            return [f(x) for x in v]
        return v

    return {k: f(v) for k, v in d.items()}


def _nan_safe(d: dict) -> dict:
    def f(k, v):
        if v is None and k in ("min", "max"):
            return NAN
        return v

    return {k: f(k, v) for k, v in d.items()}


def histogram_from_snapshot(snap: dict) -> Histogram:
    """Rebuild a single histogram from its `snapshot()` dict (accepts the
    NaN->None JSON form) — how `benchmarks/roofline.py` restores the
    bench's decode-step distribution without a full registry."""
    h = Histogram(snap["name"], snap.get("labels"),
                  lo=snap["lo"], growth=snap["growth"],
                  n_buckets=snap["n_buckets"], sample_cap=snap["sample_cap"])
    h._restore(_nan_safe(snap))
    return h


# Process-global registry for instruments that outlive any one scheduler
# (e.g. kernels/ops backend-dispatch counters, recorded at trace time).
# `Telemetry.snapshot(include_global=True)` merges it into a scheduler's
# snapshot; tests reset it via `GLOBAL.__init__()`-style `reset_global()`.
GLOBAL = MetricsRegistry()


def reset_global() -> None:
    GLOBAL._metrics.clear()
    GLOBAL._kinds.clear()
