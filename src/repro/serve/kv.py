"""Slot-pooled KV cache for continuous batching: paged pool + stripe mode.

Paged mode (default in the Scheduler for attention families): each cache
leaf that used to hold one ``max_seq`` stripe per slot becomes one shared
physical page buffer (``page`` rows per page) plus a per-slot block table
— a runtime-permuted ``vec_idx`` for the cache, resolved by attention
with the same indexed-gather discipline the HiNM kernel applies to sparse
weight tiles.  Pages flow through a host-side free list: a slot only
holds ``ceil(min(prompt+max_new, view)/page)`` pages instead of a full
``max_seq`` stripe, so pool memory scales with live tokens, not
``slots x max_seq``.  Two physical pages are reserved (see
``models/paging.py``): a scratch write-sink and a read-only kpos-sentinel
page that every unassigned block-table entry points at.  Releasing a slot
resets its freed pages' ``kpos`` rows to the sentinel, so a page recycled
to a new request can never leak rows into the old lane.

Page ownership is **refcounted** (prefix sharing, serve/prefix): a
physical page may appear in several slots' block tables at once and may
additionally be retained by the prefix index after every mapping slot
released.  The free lists hold exactly the pages with refcount zero —
``n_free_pages + n_referenced_pages == n_alloc_pages`` at all times — and
the sentinel-sweep invariant moves from "sweep on release" to "sweep when
the LAST reference drops": releasing a slot that shares a page must not
sweep its kpos rows while a co-owner still attends to them (the
kpos-ownership split).  ``map_slot`` installs shared pages into a new
slot's table without any K/V movement (refcount++), copying only a
divergent tail page (copy-on-write, donor rows past the divergence masked
out of the copy); ``deref_pages`` is the index's retention-drop hook.

``n_pages`` provisioning: an int is the explicit allocatable page count;
``"auto"`` derives one from expected occupancy (~half-view average live
length per slot, floored at one full view so a max-size request can
always admit) — the default in the Scheduler, so the paged memory win
does not silently vanish; ``None`` provisions full stripe capacity
(admission never blocks on pages).

Sharded mode (``mesh=...``): the pool is laid out for an N-device mesh.
``distributed.sharding.cache_specs`` assigns page-axis specs to the
shared pools and slot-axis specs to block tables / counters;
``paging.shard_geometry`` rounds the total page count (reserved pages
included) up so the page axis divides the mesh; the free list becomes
per-shard, and allocation draws from the fullest shard first so a slot's
pages spread across devices.  Admission/release accounting stays
host-side; page reads and writes stay device-resident — attention's
``pool[bt]`` gather resolves cross-shard pages through XLA SPMD.

Stripe mode (``page=None``) keeps the PR 2 layout: each batch lane pins a
full ``max_seq`` stripe; insertion and reset are each a single device
dispatch of per-leaf ``dynamic_update_slice_in_dim`` writes (donated).
Stripe pools shard too (batch over dp), so the conformance suite can
compare layouts on the same mesh.

``slot_len`` mirrors each slot's **actual cache rows**: prompt rows
written by prefill plus one row per decode-emitted token (a generated
token's KV lands on the step that feeds it back, so the newest sampled
token is not yet a cache row).  ``slot_capacity`` is the row reservation
made at insert; the scheduler asserts ``slot_len <= slot_capacity`` at
harvest so accounting drift fails loudly instead of silently corrupting
a neighbor page.
"""
from __future__ import annotations

import collections

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models import paging, zoo


class SlotKVCache:
    def __init__(self, cfg, n_slots: int, max_seq: int, dtype=None,
                 page: int | None = None, n_pages: int | str | None = None,
                 mesh=None, metrics=None, metrics_labels=None, flight=None,
                 flight_label: str | None = None, **cache_kw):
        # flight recorder (serve/flightrec): every host-side page decision
        # — acquire/insert/map/release, ref/deref with its sentinel sweep —
        # lands as a causally-keyed event; `flight_label` distinguishes a
        # draft pool's stream from the target pool's. None = off.
        self._flight = flight
        self._flight_pool = flight_label
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.mesh = mesh
        self._cache_kw = dict(cache_kw, dtype=dtype)
        geom = zoo.page_geometry(cfg, max_seq, page) if page else None
        self.paged = geom is not None
        self._templates: dict[int, object] = {}  # pristine batch-k caches

        # page-axis shard count: the dp axes of the mesh (the axes
        # cache_specs assigns to the page/slot axes); 1 when unsharded or
        # when the mesh has no dp axis at all (model-only mesh: the pool
        # replicates, matching cache_specs' degrade-to-replicate rule)
        self._n_shards = 1
        if mesh is not None:
            sizes = [shd._axis_size(mesh, a) for a in shd.batch_axes(mesh)]
            self._n_shards = max(1, int(np.prod([s for s in sizes if s > 0])))

        if self.paged:
            self.page = geom["page"]
            self.view_len = geom["view"]
            self.n_bt = geom["n_bt"]
            if n_pages == "auto":
                # occupancy-derived: ~half-view average live length per
                # slot, floored at one full view (max-size admission)
                alloc_req = max(self.n_bt, n_slots * ((self.n_bt + 1) // 2))
            elif n_pages is None:
                alloc_req = n_slots * self.n_bt  # full stripe capacity
            else:
                alloc_req = int(n_pages)
            sg = paging.shard_geometry(alloc_req, self._n_shards)
            self.n_pages = sg["n_pages"]
            self._pages_per_shard = sg["pages_per_shard"]
            # host-side page refcounts: free pages are exactly ref == 0;
            # a slot's table entry and the prefix index's retention each
            # hold one reference (reserved pages never enter accounting)
            self._page_ref = np.zeros((self.n_pages,), np.int64)
            self.cow_copies = 0
            self.cache = zoo.make_cache(
                cfg, n_slots, max_seq, page=self.page, n_pages=self.n_pages,
                **self._cache_kw)
            self._reset_free_pages()
            self._slot_pages: dict[int, list[int]] = {}
        else:
            self.cache = zoo.make_cache(cfg, n_slots, max_seq, **self._cache_kw)

        # sharding layout: specs (PartitionSpec pytree) + device shardings;
        # the initial pool is placed once and every jitted write constrains
        # its output back to the same layout, so page/slot writes never
        # drift off their shard
        self.specs = None
        self.shardings = None
        if mesh is not None:
            self.specs = shd.cache_specs(self.cache, mesh, cfg)
            self.shardings = shd.to_named(self.specs, mesh)
            self.cache = jax.device_put(self.cache, self.shardings)

        if self.paged:
            def insert_fn(pool, stripe, slot, row, scatter_ids, bt_row, n_alloc):
                out = zoo.paged_insert(cfg, pool, stripe, slot, row,
                                       scatter_ids, bt_row, n_alloc)
                return self._constrain(out)

            def release_fn(pool, slot, page_ids):
                return self._constrain(zoo.paged_release(cfg, pool, slot, page_ids))

            self._insert_paged = jax.jit(insert_fn, donate_argnums=(0,))
            self._release_paged = jax.jit(release_fn, donate_argnums=(0,))
        else:
            axes = zoo.cache_batch_axes(cfg, self.cache)

            def write_row(pool, batched, slot, row):
                # copy slot-row `row` of a batch-k cache into pool slot `slot`
                def f(c, o, a):
                    one = jax.lax.dynamic_slice_in_dim(o, row, 1, axis=a)
                    return jax.lax.dynamic_update_slice_in_dim(
                        c, one.astype(c.dtype), slot, axis=a)

                return self._constrain(jax.tree.map(f, pool, batched, axes))

            self._write_row = jax.jit(write_row, donate_argnums=(0,))

        self._free = list(range(n_slots))
        # host mirror of each slot's cache-row count and row reservation
        self.slot_len = np.zeros((n_slots,), np.int64)
        self._slot_cap = np.zeros((n_slots,), np.int64)
        # speculative commit/rollback jits, one per verify width (n_written)
        self._rollback_jits: dict[int, object] = {}

        # pool occupancy instruments (telemetry.MetricsRegistry): gauges
        # track slots/pages in use on every host-side accounting change
        # (the free-page gauge's `min` is the pool's low-water mark), a
        # counter tallies speculative rollback sweeps. `metrics=None`
        # (standalone pools) skips all of it.
        self._m_slots = self._m_free_pages = self._m_used_pages = None
        self._m_rollbacks = self._m_shared = self._m_cow = None
        if metrics is not None:
            lb = dict(metrics_labels or {})
            self._m_slots = metrics.gauge("kv_slots_in_use", labels=lb)
            self._m_rollbacks = metrics.counter("kv_rollback_sweeps", labels=lb)
            metrics.gauge("kv_pool_bytes", labels=lb).set(self.pool_bytes())
            if self.paged:
                self._m_free_pages = metrics.gauge("kv_free_pages", labels=lb)
                self._m_used_pages = metrics.gauge("kv_pages_in_use", labels=lb)
                self._m_shared = metrics.gauge("kv_shared_pages", labels=lb)
                self._m_cow = metrics.counter("kv_cow_copies", labels=lb)
            self._observe_occupancy()

    def _emit(self, kind: str, **data) -> None:
        if self._flight is not None:
            if self._flight_pool is not None:
                data["pool"] = self._flight_pool
            self._flight.emit(kind, **data)

    def _observe_occupancy(self) -> None:
        if self._m_slots is None:
            return
        self._m_slots.set(self.n_slots - len(self._free))
        if self._m_free_pages is not None:
            free = self.n_free_pages
            self._m_free_pages.set(free)
            self._m_used_pages.set(self.n_alloc_pages - free)
            self._m_shared.set(self.n_shared_pages)

    def _constrain(self, tree):
        """Pin a jitted cache update's output to the pool layout."""
        if self.shardings is None:
            return tree
        return jax.lax.with_sharding_constraint(tree, self.shardings)

    def _reset_free_pages(self) -> None:
        """Pristine per-shard free lists (shard of page p = p // per_shard);
        the reserved scratch/sentinel ids never enter a list."""
        self._free_pages = [collections.deque() for _ in range(self._n_shards)]
        for p in range(paging.N_RESERVED, self.n_pages):
            self._free_pages[p // self._pages_per_shard].append(p)
        self._page_ref[:] = 0

    def _pop_pages(self, n: int) -> list[int]:
        """Draw `n` free pages, fullest shard first (ties: lowest shard) —
        a slot's pages spread across the mesh instead of draining shard 0.
        Popped pages leave with exactly one reference (the caller's)."""
        pages = []
        for _ in range(n):
            s = max(range(self._n_shards),
                    key=lambda i: (len(self._free_pages[i]), -i))
            pages.append(self._free_pages[s].popleft())
        self._page_ref[pages] = 1
        return pages

    def _push_pages(self, pages) -> None:
        for p in pages:
            assert self._page_ref[p] == 0, (
                f"page {p} returned to the free list with "
                f"{self._page_ref[p]} live references")
            self._free_pages[p // self._pages_per_shard].append(p)

    # -- page refcounts (prefix sharing) --------------------------------------

    def page_ref(self, page: int) -> int:
        """Live reference count of a physical page (slots mapping it plus
        the prefix index's retention reference)."""
        return int(self._page_ref[page])

    def ref_pages(self, pages) -> None:
        """Take one additional reference on each page (all must be live —
        a zero-ref page is on a free list and has nothing to share)."""
        for p in pages:
            assert self._page_ref[p] >= 1, f"page {p} is free, cannot share"
            self._page_ref[p] += 1
        if pages:
            self._emit("kv_ref", pages=[int(p) for p in pages])

    def deref_pages(self, pages) -> int:
        """Drop one reference per page.  Pages whose LAST reference drops
        are swept (kpos rows back to the sentinel — only now is it safe:
        no block table and no index entry can reach them) and returned to
        the free lists.  Returns the number of pages freed."""
        freed = []
        for p in pages:
            assert self._page_ref[p] >= 1, f"page {p} double-freed"
            self._page_ref[p] -= 1
            if self._page_ref[p] == 0:
                freed.append(p)
        if pages:
            # `freed` is exactly the sentinel-sweep set: pages whose LAST
            # reference just dropped
            self._emit("kv_deref", pages=[int(p) for p in pages],
                       freed=[int(p) for p in freed])
        if freed:
            ids = np.full((self.n_bt,), paging.SCRATCH_PAGE, np.int32)
            ids[: len(freed)] = freed
            self.cache = self._sweep_paged()(self.cache, jnp.asarray(ids))
            self._push_pages(freed)
            self._observe_occupancy()
        return len(freed)

    def _sweep_paged(self):
        """Jitted table-free kpos sweep (built lazily: only prefix-sharing
        families ever deref a page no slot owns)."""
        jit = getattr(self, "_sweep_jit", None)
        if jit is None:
            cfg = self.cfg

            def sweep_fn(pool, page_ids):
                return self._constrain(zoo.paged_sweep(cfg, pool, page_ids))

            jit = self._sweep_jit = jax.jit(sweep_fn, donate_argnums=(0,))
        return jit

    def template(self, batch: int = 1):
        """Pristine batch-`batch` stripe cache: prefill input / slot-reset
        source (prefill always runs on stripes; paged insert scatters the
        prefilled rows into pages).  On a mesh the template is replicated so
        prefill and the pool computation share one device set."""
        if batch not in self._templates:
            t = zoo.make_cache(self.cfg, batch, self.max_seq, **self._cache_kw)
            if self.mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec

                t = jax.device_put(t, NamedSharding(self.mesh, PartitionSpec()))
            self._templates[batch] = t
        return self._templates[batch]

    # -- page accounting ------------------------------------------------------

    def pages_needed(self, rows: int) -> int:
        """Pages covering `rows` cache rows (capped at the view: a windowed
        ring reuses its pages in place once positions wrap)."""
        rows = min(rows, self.view_len)
        return max(1, -(-rows // self.page))

    @property
    def n_free_pages(self) -> int:
        if not self.paged:
            return 1 << 62
        return sum(len(d) for d in self._free_pages)

    @property
    def n_alloc_pages(self) -> int:
        """Total allocatable pages (excludes the two reserved pages)."""
        return self.n_pages - paging.N_RESERVED if self.paged else 1 << 62

    @property
    def n_referenced_pages(self) -> int:
        """Pages with at least one live reference.  The conservation law
        ``n_free_pages + n_referenced_pages == n_alloc_pages`` holds at
        every step — a page is on a free list exactly when ref == 0."""
        if not self.paged:
            return 0
        return int((self._page_ref[paging.N_RESERVED:] > 0).sum())

    @property
    def n_shared_pages(self) -> int:
        """Pages with more than one live reference (mapped by several
        slots, or by a slot plus the prefix index's retention)."""
        if not self.paged:
            return 0
        return int((self._page_ref[paging.N_RESERVED:] > 1).sum())

    @property
    def n_live_pages(self) -> int:
        """Distinct pages mapped by at least one live slot's block table.
        The working-set measure for memory pressure: retained prefix
        pages (referenced by the index alone) are reclaimable cache, not
        demand — sharing shrinks THIS number, because co-resident slots
        map the same physical pages."""
        if not self.paged:
            return 0
        live = set()
        for pages in self._slot_pages.values():
            live.update(pages)
        return len(live)

    @property
    def page_sharded(self) -> bool:
        """True when the shared pool leaves are actually split on their
        page axis.  The paged-attention kernel is a single-device program,
        so the Scheduler defers to the SPMD gather path on a page-sharded
        pool — unless ``KNOBS.paged_attn_sharded`` opted the layout into
        replication (then this is False and the kernel runs everywhere)."""
        if not self.paged or self.specs is None:
            return False
        import jax.sharding

        is_spec = lambda x: isinstance(x, jax.sharding.PartitionSpec)
        roles = jax.tree.leaves(zoo.cache_shard_roles(self.cfg, self.cache))
        specs = jax.tree.leaves(self.specs, is_leaf=is_spec)
        return any(r == "page" and len(s) > 1 and s[1] is not None
                   for r, s in zip(roles, specs))

    def can_admit(self, reserve_rows: int, n_shared: int = 0) -> bool:
        """Would a request needing `reserve_rows` cache rows fit right now?
        ``n_shared`` pages of its budget arrive via the prefix index
        (refcount++, no free-list draw), so only the rest must be free."""
        if not self._free:
            return False
        return (not self.paged
                or self.pages_needed(reserve_rows) - n_shared
                <= self.n_free_pages)

    def slot_capacity(self, slot: int) -> int:
        """Cache rows reserved for `slot` at insert time."""
        return int(self._slot_cap[slot])

    def slot_pages(self, slot: int) -> list[int]:
        """Physical pages backing `slot`, block-table order (logical page p
        of the slot's view is pages[p])."""
        return list(self._slot_pages.get(slot, ()))

    def pool_bytes(self) -> int:
        """Device bytes held by the pool cache pytree (global, all shards)."""
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(self.cache))

    # -- slot lifecycle -------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    def acquire(self) -> int:
        if not self._free:
            raise RuntimeError("no free slots")
        slot = self._free.pop(0)
        self._emit("kv_acquire", slot=slot)
        self._observe_occupancy()
        return slot

    def insert(self, slot: int, cache, length: int, row: int = 0,
               reserve: int | None = None) -> None:
        """Write row `row` of a prefilled batch-k stripe cache into `slot`.

        `length` is the row count actually written (true prompt rows);
        `reserve` is the row budget the request may grow to (prompt +
        max_new_tokens) — in paged mode it sizes the page allocation."""
        reserve = length if reserve is None else reserve
        if self.paged:
            n_alloc = self.pages_needed(reserve)
            if n_alloc > self.n_free_pages:
                raise RuntimeError(
                    f"slot {slot}: {n_alloc} pages needed, "
                    f"{self.n_free_pages} free")
            pages = self._pop_pages(n_alloc)
            ids = np.full((self.n_bt,), paging.SCRATCH_PAGE, np.int32)
            bt_row = np.full((self.n_bt,), paging.SENTINEL_PAGE, np.int32)
            ids[:n_alloc] = bt_row[:n_alloc] = pages
            self.cache = self._insert_paged(
                self.cache, cache, slot, row, jnp.asarray(ids),
                jnp.asarray(bt_row), np.int32(n_alloc))
            self._slot_pages[slot] = pages
        else:
            self.cache = self._write_row(self.cache, cache, slot, row)
        self._emit("kv_insert", slot=slot, rows=length, reserve=reserve,
                   pages=([int(p) for p in self._slot_pages[slot]]
                          if self.paged else []))
        # row budget the request may legally grow to; a windowed ring wraps
        # within its pages, so `reserve` (not n_alloc * page) is the bound
        self._slot_cap[slot] = reserve
        self.slot_len[slot] = length
        self._observe_occupancy()

    def map_slot(self, slot: int, shared_pages, shared_rows: int,
                 reserve: int, cow_src: int | None = None,
                 cow_rows: int = 0) -> list[int]:
        """Map `slot` onto shared prefix pages plus fresh private pages
        WITHOUT a stripe scatter (prefix sharing, serve/prefix).

        ``shared_pages`` (prefix order, ``shared_rows = len * page`` rows)
        are live pages another owner wrote: each gains a reference and
        lands in the slot's block table in place — zero K/V movement.  A
        divergent tail (``cow_src``/``cow_rows``) is copied onto the first
        fresh page, donor rows past the divergence masked out of the copy
        (copy-on-write).  The slot's ``pos`` starts at the mapped row
        count; the caller prefills only the unshared suffix through the
        multi-token extension path.  Returns the slot's full page list."""
        assert self.paged, "map_slot requires a paged pool"
        total = self.pages_needed(reserve)
        n_shared = len(shared_pages)
        n_fresh = total - n_shared
        assert n_fresh >= 1, "a mapped slot still needs >= 1 private page"
        if n_fresh > self.n_free_pages:
            raise RuntimeError(
                f"slot {slot}: {n_fresh} fresh pages needed, "
                f"{self.n_free_pages} free")
        fresh = self._pop_pages(n_fresh)
        self.ref_pages(shared_pages)
        pages = list(shared_pages) + fresh
        bt_row = np.full((self.n_bt,), paging.SENTINEL_PAGE, np.int32)
        bt_row[:total] = pages
        mapped_rows = shared_rows + cow_rows
        self.cache = self._map_paged()(
            self.cache, slot, jnp.asarray(bt_row), np.int32(total),
            np.int32(mapped_rows))
        if cow_src is not None and cow_rows > 0:
            # the CoW page is fresh[0]: logical page n_shared, right after
            # the full shared chain
            self.cache = self._cow_paged()(
                self.cache, np.int32(fresh[0]), np.int32(cow_src),
                np.int32(cow_rows))
            self.cow_copies += 1
            if self._m_cow is not None:
                self._m_cow.inc()
        self._slot_pages[slot] = pages
        self._slot_cap[slot] = reserve
        self.slot_len[slot] = mapped_rows
        self._emit("kv_map", slot=slot, shared=[int(p) for p in shared_pages],
                   fresh=[int(p) for p in fresh],
                   cow_src=None if cow_src is None else int(cow_src),
                   cow_rows=int(cow_rows), rows=mapped_rows, reserve=reserve)
        self._observe_occupancy()
        return pages

    def _map_paged(self):
        jit = getattr(self, "_map_jit", None)
        if jit is None:
            cfg = self.cfg

            def map_fn(pool, slot, bt_row, n_alloc, pos):
                out = zoo.paged_map(cfg, pool, slot, bt_row, n_alloc, pos)
                return self._constrain(out)

            jit = self._map_jit = jax.jit(map_fn, donate_argnums=(0,))
        return jit

    def _cow_paged(self):
        jit = getattr(self, "_cow_jit", None)
        if jit is None:
            cfg = self.cfg

            def cow_fn(pool, dst, src, keep_rows):
                out = zoo.paged_copy_page(cfg, pool, dst, src, keep_rows)
                return self._constrain(out)

            jit = self._cow_jit = jax.jit(cow_fn, donate_argnums=(0,))
        return jit

    def release(self, slot: int) -> None:
        """Reset `slot` to pristine state and return it to the free lists.
        In paged mode each of its pages drops one reference; only pages
        whose LAST reference dropped are swept (kpos back to the sentinel)
        and freed — a page the prefix index retains, or that another slot
        still maps, keeps its rows live (the sentinel-sweep invariant under
        sharing).  The slot's block table resets either way."""
        if self.paged:
            pages = self._slot_pages.pop(slot, [])
            freed = []
            for p in pages:
                assert self._page_ref[p] >= 1, f"page {p} double-freed"
                self._page_ref[p] -= 1
                if self._page_ref[p] == 0:
                    freed.append(p)
            ids = np.full((self.n_bt,), paging.SCRATCH_PAGE, np.int32)
            ids[: len(freed)] = freed
            self.cache = self._release_paged(
                self.cache, slot, jnp.asarray(ids))
            self._push_pages(freed)
            self._emit("kv_release", slot=slot,
                       pages=[int(p) for p in pages],
                       freed=[int(p) for p in freed])
        else:
            self.cache = self._write_row(self.cache, self.template(), slot, 0)
            self._emit("kv_release", slot=slot, pages=[], freed=[])
        self.slot_len[slot] = 0
        self._slot_cap[slot] = 0
        self._free.append(slot)
        self._observe_occupancy()

    def rollback(self, pos0, keep, n_written: int, undo=None) -> None:
        """Speculative commit/rollback (serve/spec): of the ``n_written``
        candidate rows a verify step wrote per slot starting at ``pos0``
        (B,), keep the accepted ``keep`` (B,) and rewind the rest — kpos
        swept back to the sentinel (paged: rejected rows become exactly
        as unreachable as unwritten ones; the sweep of a row that went to
        the scratch page is redirected there and is a no-op) or restored
        from undo snapshots (sequential verifiers), with every position
        counter rewound to ``pos0 + keep``.

        No page moves: rejected rows sit inside the slot's existing
        reservation, so the (per-shard) free list, ``pool_bytes`` and the
        ``slot_len``/``slot_capacity`` accounting are untouched — the
        caller advances ``slot_len`` by the emitted count it harvests,
        which equals ``keep`` by construction.  One donated dispatch,
        pinned back to the pool layout under a mesh."""
        jit = self._rollback_jits.get(n_written)
        if jit is None:
            cfg = self.cfg

            def rollback_fn(cache, undo, pos0, keep):
                out = zoo.cache_rollback(cfg, cache, undo, pos0, keep,
                                         n_written)
                return self._constrain(out)

            jit = self._rollback_jits[n_written] = jax.jit(
                rollback_fn, donate_argnums=(0,))
        self.cache = jit(self.cache, undo, jnp.asarray(pos0, jnp.int32),
                         jnp.asarray(keep, jnp.int32))
        if self._m_rollbacks is not None:
            self._m_rollbacks.inc()

    def note_scan_rollbacks(self, n: int) -> None:
        """Account `n` rollback sweeps executed in-jit by a fused scan.
        The scheduler's fused draft/verify loop inlines `zoo.cache_rollback`
        into its cycle body (one per cycle, device-resident), so `rollback`
        never sees them — this keeps the `kv_rollback_sweeps` counter
        meaning "rollback sweeps applied to the pool" in both modes."""
        if self._m_rollbacks is not None and n:
            self._m_rollbacks.inc(n)

    def reset_all(self) -> None:
        if self.paged:
            self.cache = zoo.make_cache(
                self.cfg, self.n_slots, self.max_seq, page=self.page,
                n_pages=self.n_pages, **self._cache_kw)
            self._reset_free_pages()
            self._slot_pages = {}
            self.cow_copies = 0
        else:
            self.cache = zoo.make_cache(
                self.cfg, self.n_slots, self.max_seq, **self._cache_kw)
        if self.shardings is not None:
            self.cache = jax.device_put(self.cache, self.shardings)
        self._free = list(range(self.n_slots))
        self.slot_len[:] = 0
        self._slot_cap[:] = 0
        self._observe_occupancy()
