"""Slot-pooled KV cache for continuous batching: paged pool + stripe mode.

Paged mode (default in the Scheduler for attention families): each cache
leaf that used to hold one ``max_seq`` stripe per slot becomes one shared
physical page buffer (``page`` rows per page) plus a per-slot block table
— a runtime-permuted ``vec_idx`` for the cache, resolved by attention
with the same indexed-gather discipline the HiNM kernel applies to sparse
weight tiles.  Pages flow through a host-side free list: a slot only
holds ``ceil(min(prompt+max_new, view)/page)`` pages instead of a full
``max_seq`` stripe, so pool memory scales with live tokens, not
``slots x max_seq``.  Two physical pages are reserved (see
``models/paging.py``): a scratch write-sink and a read-only kpos-sentinel
page that every unassigned block-table entry points at.  Releasing a slot
resets its freed pages' ``kpos`` rows to the sentinel, so a page recycled
to a new request can never leak rows into the old lane.

Stripe mode (``page=None``) keeps the PR 2 layout: each batch lane pins a
full ``max_seq`` stripe; insertion and reset are each a single device
dispatch of per-leaf ``dynamic_update_slice_in_dim`` writes (donated).

``slot_len`` mirrors each slot's **actual cache rows**: prompt rows
written by prefill plus one row per decode-emitted token (a generated
token's KV lands on the step that feeds it back, so the newest sampled
token is not yet a cache row).  ``slot_capacity`` is the row reservation
made at insert; the scheduler asserts ``slot_len <= slot_capacity`` at
harvest so accounting drift fails loudly instead of silently corrupting
a neighbor page.
"""
from __future__ import annotations

import collections

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import paging, zoo


class SlotKVCache:
    def __init__(self, cfg, n_slots: int, max_seq: int, dtype=None,
                 page: int | None = None, n_pages: int | None = None,
                 **cache_kw):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self._cache_kw = dict(cache_kw, dtype=dtype)
        geom = zoo.page_geometry(cfg, max_seq, page) if page else None
        self.paged = geom is not None
        self._templates: dict[int, object] = {}  # pristine batch-k caches

        if self.paged:
            self.page = geom["page"]
            self.view_len = geom["view"]
            self.n_bt = geom["n_bt"]
            # `n_pages` = allocatable pages; None = full stripe capacity
            alloc_pages = n_slots * self.n_bt if n_pages is None else n_pages
            self.n_pages = paging.N_RESERVED + alloc_pages
            self.cache = zoo.make_cache(
                cfg, n_slots, max_seq, page=self.page, n_pages=self.n_pages,
                **self._cache_kw)
            self._free_pages = collections.deque(
                range(paging.N_RESERVED, self.n_pages))
            self._slot_pages: dict[int, list[int]] = {}

            def insert_fn(pool, stripe, slot, row, scatter_ids, bt_row, n_alloc):
                return zoo.paged_insert(cfg, pool, stripe, slot, row,
                                        scatter_ids, bt_row, n_alloc)

            def release_fn(pool, slot, page_ids):
                return zoo.paged_release(cfg, pool, slot, page_ids)

            self._insert_paged = jax.jit(insert_fn, donate_argnums=(0,))
            self._release_paged = jax.jit(release_fn, donate_argnums=(0,))
        else:
            self.cache = zoo.make_cache(cfg, n_slots, max_seq, **self._cache_kw)
            axes = zoo.cache_batch_axes(cfg, self.cache)

            def write_row(pool, batched, slot, row):
                # copy slot-row `row` of a batch-k cache into pool slot `slot`
                def f(c, o, a):
                    one = jax.lax.dynamic_slice_in_dim(o, row, 1, axis=a)
                    return jax.lax.dynamic_update_slice_in_dim(
                        c, one.astype(c.dtype), slot, axis=a)

                return jax.tree.map(f, pool, batched, axes)

            self._write_row = jax.jit(write_row, donate_argnums=(0,))

        self._free = list(range(n_slots))
        # host mirror of each slot's cache-row count and row reservation
        self.slot_len = np.zeros((n_slots,), np.int64)
        self._slot_cap = np.zeros((n_slots,), np.int64)

    def template(self, batch: int = 1):
        """Pristine batch-`batch` stripe cache: prefill input / slot-reset
        source (prefill always runs on stripes; paged insert scatters the
        prefilled rows into pages)."""
        if batch not in self._templates:
            self._templates[batch] = zoo.make_cache(
                self.cfg, batch, self.max_seq, **self._cache_kw)
        return self._templates[batch]

    # -- page accounting ------------------------------------------------------

    def pages_needed(self, rows: int) -> int:
        """Pages covering `rows` cache rows (capped at the view: a windowed
        ring reuses its pages in place once positions wrap)."""
        rows = min(rows, self.view_len)
        return max(1, -(-rows // self.page))

    @property
    def n_free_pages(self) -> int:
        return len(self._free_pages) if self.paged else 1 << 62

    @property
    def n_alloc_pages(self) -> int:
        """Total allocatable pages (excludes the two reserved pages)."""
        return self.n_pages - paging.N_RESERVED if self.paged else 1 << 62

    def can_admit(self, reserve_rows: int) -> bool:
        """Would a request needing `reserve_rows` cache rows fit right now?"""
        if not self._free:
            return False
        return (not self.paged
                or self.pages_needed(reserve_rows) <= len(self._free_pages))

    def slot_capacity(self, slot: int) -> int:
        """Cache rows reserved for `slot` at insert time."""
        return int(self._slot_cap[slot])

    def pool_bytes(self) -> int:
        """Device bytes held by the pool cache pytree."""
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(self.cache))

    # -- slot lifecycle -------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    def acquire(self) -> int:
        if not self._free:
            raise RuntimeError("no free slots")
        return self._free.pop(0)

    def insert(self, slot: int, cache, length: int, row: int = 0,
               reserve: int | None = None) -> None:
        """Write row `row` of a prefilled batch-k stripe cache into `slot`.

        `length` is the row count actually written (true prompt rows);
        `reserve` is the row budget the request may grow to (prompt +
        max_new_tokens) — in paged mode it sizes the page allocation."""
        reserve = length if reserve is None else reserve
        if self.paged:
            n_alloc = self.pages_needed(reserve)
            if n_alloc > len(self._free_pages):
                raise RuntimeError(
                    f"slot {slot}: {n_alloc} pages needed, "
                    f"{len(self._free_pages)} free")
            pages = [self._free_pages.popleft() for _ in range(n_alloc)]
            ids = np.full((self.n_bt,), paging.SCRATCH_PAGE, np.int32)
            bt_row = np.full((self.n_bt,), paging.SENTINEL_PAGE, np.int32)
            ids[:n_alloc] = bt_row[:n_alloc] = pages
            self.cache = self._insert_paged(
                self.cache, cache, slot, row, jnp.asarray(ids),
                jnp.asarray(bt_row), np.int32(n_alloc))
            self._slot_pages[slot] = pages
        else:
            self.cache = self._write_row(self.cache, cache, slot, row)
        # row budget the request may legally grow to; a windowed ring wraps
        # within its pages, so `reserve` (not n_alloc * page) is the bound
        self._slot_cap[slot] = reserve
        self.slot_len[slot] = length

    def release(self, slot: int) -> None:
        """Reset `slot` to pristine state and return it (and, in paged mode,
        its pages — kpos rows back to the sentinel) to the free lists."""
        if self.paged:
            pages = self._slot_pages.pop(slot, [])
            ids = np.full((self.n_bt,), paging.SCRATCH_PAGE, np.int32)
            ids[: len(pages)] = pages
            self.cache = self._release_paged(
                self.cache, slot, jnp.asarray(ids))
            self._free_pages.extend(pages)
        else:
            self.cache = self._write_row(self.cache, self.template(), slot, 0)
        self.slot_len[slot] = 0
        self._slot_cap[slot] = 0
        self._free.append(slot)

    def reset_all(self) -> None:
        if self.paged:
            self.cache = zoo.make_cache(
                self.cfg, self.n_slots, self.max_seq, page=self.page,
                n_pages=self.n_pages, **self._cache_kw)
            self._free_pages = collections.deque(
                range(paging.N_RESERVED, self.n_pages))
            self._slot_pages = {}
        else:
            self.cache = zoo.make_cache(
                self.cfg, self.n_slots, self.max_seq, **self._cache_kw)
        self._free = list(range(self.n_slots))
        self.slot_len[:] = 0
        self._slot_cap[:] = 0
