"""Slot-pooled KV cache for continuous batching.

The pool is one family cache pytree (`zoo.make_cache`) of width
`n_slots`: each batch lane is a slot hosting one in-flight request at its
own decode position (the family caches carry per-slot `pos`/`kpos`).
Slots are recycled through a free list; insertion and reset are each a
single device dispatch of per-leaf `dynamic_update_slice_in_dim` writes
(donated, so the pool updates in place instead of reallocating O(pool)
memory per admission).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.models import zoo


class SlotKVCache:
    def __init__(self, cfg, n_slots: int, max_seq: int, dtype=None, **cache_kw):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self._cache_kw = dict(cache_kw, dtype=dtype)
        self.cache = zoo.make_cache(cfg, n_slots, max_seq, **self._cache_kw)
        self._templates: dict[int, object] = {}  # pristine batch-k caches
        axes = zoo.cache_batch_axes(cfg, self.cache)

        def write_row(pool, batched, slot, row):
            # copy slot-row `row` of a batch-k cache into pool slot `slot`
            def f(c, o, a):
                one = jax.lax.dynamic_slice_in_dim(o, row, 1, axis=a)
                return jax.lax.dynamic_update_slice_in_dim(
                    c, one.astype(c.dtype), slot, axis=a)

            return jax.tree.map(f, pool, batched, axes)

        self._write_row = jax.jit(write_row, donate_argnums=(0,))
        self._free = list(range(n_slots))
        # host mirror of each slot's sequence length (prompt + generated so
        # far) for admission guards and introspection
        self.slot_len = np.zeros((n_slots,), np.int64)

    def template(self, batch: int = 1):
        """Pristine batch-`batch` cache: prefill input / slot-reset source."""
        if batch not in self._templates:
            self._templates[batch] = zoo.make_cache(
                self.cfg, batch, self.max_seq, **self._cache_kw)
        return self._templates[batch]

    # -- slot lifecycle -----------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    def acquire(self) -> int:
        if not self._free:
            raise RuntimeError("no free slots")
        return self._free.pop(0)

    def insert(self, slot: int, cache, length: int, row: int = 0) -> None:
        """Write row `row` of a prefilled batch-k cache into `slot`."""
        self.cache = self._write_row(self.cache, cache, slot, row)
        self.slot_len[slot] = length

    def release(self, slot: int) -> None:
        """Reset `slot` to pristine state (kpos -> +inf sentinel, pos -> 0,
        recurrent state -> initial) and return it to the free list."""
        self.cache = self._write_row(self.cache, self.template(), slot, 0)
        self.slot_len[slot] = 0
        self._free.append(slot)

    def reset_all(self) -> None:
        self.cache = zoo.make_cache(
            self.cfg, self.n_slots, self.max_seq, **self._cache_kw)
        self._free = list(range(self.n_slots))
        self.slot_len[:] = 0
