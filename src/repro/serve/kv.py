"""Slot-pooled KV cache for continuous batching: paged pool + stripe mode.

Paged mode (default in the Scheduler for attention families): each cache
leaf that used to hold one ``max_seq`` stripe per slot becomes one shared
physical page buffer (``page`` rows per page) plus a per-slot block table
— a runtime-permuted ``vec_idx`` for the cache, resolved by attention
with the same indexed-gather discipline the HiNM kernel applies to sparse
weight tiles.  Pages flow through a host-side free list: a slot only
holds ``ceil(min(prompt+max_new, view)/page)`` pages instead of a full
``max_seq`` stripe, so pool memory scales with live tokens, not
``slots x max_seq``.  Two physical pages are reserved (see
``models/paging.py``): a scratch write-sink and a read-only kpos-sentinel
page that every unassigned block-table entry points at.  Releasing a slot
resets its freed pages' ``kpos`` rows to the sentinel, so a page recycled
to a new request can never leak rows into the old lane.

``n_pages`` provisioning: an int is the explicit allocatable page count;
``"auto"`` derives one from expected occupancy (~half-view average live
length per slot, floored at one full view so a max-size request can
always admit) — the default in the Scheduler, so the paged memory win
does not silently vanish; ``None`` provisions full stripe capacity
(admission never blocks on pages).

Sharded mode (``mesh=...``): the pool is laid out for an N-device mesh.
``distributed.sharding.cache_specs`` assigns page-axis specs to the
shared pools and slot-axis specs to block tables / counters;
``paging.shard_geometry`` rounds the total page count (reserved pages
included) up so the page axis divides the mesh; the free list becomes
per-shard, and allocation draws from the fullest shard first so a slot's
pages spread across devices.  Admission/release accounting stays
host-side; page reads and writes stay device-resident — attention's
``pool[bt]`` gather resolves cross-shard pages through XLA SPMD.

Stripe mode (``page=None``) keeps the PR 2 layout: each batch lane pins a
full ``max_seq`` stripe; insertion and reset are each a single device
dispatch of per-leaf ``dynamic_update_slice_in_dim`` writes (donated).
Stripe pools shard too (batch over dp), so the conformance suite can
compare layouts on the same mesh.

``slot_len`` mirrors each slot's **actual cache rows**: prompt rows
written by prefill plus one row per decode-emitted token (a generated
token's KV lands on the step that feeds it back, so the newest sampled
token is not yet a cache row).  ``slot_capacity`` is the row reservation
made at insert; the scheduler asserts ``slot_len <= slot_capacity`` at
harvest so accounting drift fails loudly instead of silently corrupting
a neighbor page.
"""
from __future__ import annotations

import collections

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models import paging, zoo


class SlotKVCache:
    def __init__(self, cfg, n_slots: int, max_seq: int, dtype=None,
                 page: int | None = None, n_pages: int | str | None = None,
                 mesh=None, metrics=None, metrics_labels=None, **cache_kw):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.mesh = mesh
        self._cache_kw = dict(cache_kw, dtype=dtype)
        geom = zoo.page_geometry(cfg, max_seq, page) if page else None
        self.paged = geom is not None
        self._templates: dict[int, object] = {}  # pristine batch-k caches

        # page-axis shard count: the dp axes of the mesh (the axes
        # cache_specs assigns to the page/slot axes); 1 when unsharded or
        # when the mesh has no dp axis at all (model-only mesh: the pool
        # replicates, matching cache_specs' degrade-to-replicate rule)
        self._n_shards = 1
        if mesh is not None:
            sizes = [shd._axis_size(mesh, a) for a in shd.batch_axes(mesh)]
            self._n_shards = max(1, int(np.prod([s for s in sizes if s > 0])))

        if self.paged:
            self.page = geom["page"]
            self.view_len = geom["view"]
            self.n_bt = geom["n_bt"]
            if n_pages == "auto":
                # occupancy-derived: ~half-view average live length per
                # slot, floored at one full view (max-size admission)
                alloc_req = max(self.n_bt, n_slots * ((self.n_bt + 1) // 2))
            elif n_pages is None:
                alloc_req = n_slots * self.n_bt  # full stripe capacity
            else:
                alloc_req = int(n_pages)
            sg = paging.shard_geometry(alloc_req, self._n_shards)
            self.n_pages = sg["n_pages"]
            self._pages_per_shard = sg["pages_per_shard"]
            self.cache = zoo.make_cache(
                cfg, n_slots, max_seq, page=self.page, n_pages=self.n_pages,
                **self._cache_kw)
            self._reset_free_pages()
            self._slot_pages: dict[int, list[int]] = {}
        else:
            self.cache = zoo.make_cache(cfg, n_slots, max_seq, **self._cache_kw)

        # sharding layout: specs (PartitionSpec pytree) + device shardings;
        # the initial pool is placed once and every jitted write constrains
        # its output back to the same layout, so page/slot writes never
        # drift off their shard
        self.specs = None
        self.shardings = None
        if mesh is not None:
            self.specs = shd.cache_specs(self.cache, mesh, cfg)
            self.shardings = shd.to_named(self.specs, mesh)
            self.cache = jax.device_put(self.cache, self.shardings)

        if self.paged:
            def insert_fn(pool, stripe, slot, row, scatter_ids, bt_row, n_alloc):
                out = zoo.paged_insert(cfg, pool, stripe, slot, row,
                                       scatter_ids, bt_row, n_alloc)
                return self._constrain(out)

            def release_fn(pool, slot, page_ids):
                return self._constrain(zoo.paged_release(cfg, pool, slot, page_ids))

            self._insert_paged = jax.jit(insert_fn, donate_argnums=(0,))
            self._release_paged = jax.jit(release_fn, donate_argnums=(0,))
        else:
            axes = zoo.cache_batch_axes(cfg, self.cache)

            def write_row(pool, batched, slot, row):
                # copy slot-row `row` of a batch-k cache into pool slot `slot`
                def f(c, o, a):
                    one = jax.lax.dynamic_slice_in_dim(o, row, 1, axis=a)
                    return jax.lax.dynamic_update_slice_in_dim(
                        c, one.astype(c.dtype), slot, axis=a)

                return self._constrain(jax.tree.map(f, pool, batched, axes))

            self._write_row = jax.jit(write_row, donate_argnums=(0,))

        self._free = list(range(n_slots))
        # host mirror of each slot's cache-row count and row reservation
        self.slot_len = np.zeros((n_slots,), np.int64)
        self._slot_cap = np.zeros((n_slots,), np.int64)
        # speculative commit/rollback jits, one per verify width (n_written)
        self._rollback_jits: dict[int, object] = {}

        # pool occupancy instruments (telemetry.MetricsRegistry): gauges
        # track slots/pages in use on every host-side accounting change
        # (the free-page gauge's `min` is the pool's low-water mark), a
        # counter tallies speculative rollback sweeps. `metrics=None`
        # (standalone pools) skips all of it.
        self._m_slots = self._m_free_pages = self._m_used_pages = None
        self._m_rollbacks = None
        if metrics is not None:
            lb = dict(metrics_labels or {})
            self._m_slots = metrics.gauge("kv_slots_in_use", labels=lb)
            self._m_rollbacks = metrics.counter("kv_rollback_sweeps", labels=lb)
            metrics.gauge("kv_pool_bytes", labels=lb).set(self.pool_bytes())
            if self.paged:
                self._m_free_pages = metrics.gauge("kv_free_pages", labels=lb)
                self._m_used_pages = metrics.gauge("kv_pages_in_use", labels=lb)
            self._observe_occupancy()

    def _observe_occupancy(self) -> None:
        if self._m_slots is None:
            return
        self._m_slots.set(self.n_slots - len(self._free))
        if self._m_free_pages is not None:
            free = self.n_free_pages
            self._m_free_pages.set(free)
            self._m_used_pages.set(self.n_alloc_pages - free)

    def _constrain(self, tree):
        """Pin a jitted cache update's output to the pool layout."""
        if self.shardings is None:
            return tree
        return jax.lax.with_sharding_constraint(tree, self.shardings)

    def _reset_free_pages(self) -> None:
        """Pristine per-shard free lists (shard of page p = p // per_shard);
        the reserved scratch/sentinel ids never enter a list."""
        self._free_pages = [collections.deque() for _ in range(self._n_shards)]
        for p in range(paging.N_RESERVED, self.n_pages):
            self._free_pages[p // self._pages_per_shard].append(p)

    def _pop_pages(self, n: int) -> list[int]:
        """Draw `n` free pages, fullest shard first (ties: lowest shard) —
        a slot's pages spread across the mesh instead of draining shard 0."""
        pages = []
        for _ in range(n):
            s = max(range(self._n_shards),
                    key=lambda i: (len(self._free_pages[i]), -i))
            pages.append(self._free_pages[s].popleft())
        return pages

    def _push_pages(self, pages) -> None:
        for p in pages:
            self._free_pages[p // self._pages_per_shard].append(p)

    def template(self, batch: int = 1):
        """Pristine batch-`batch` stripe cache: prefill input / slot-reset
        source (prefill always runs on stripes; paged insert scatters the
        prefilled rows into pages).  On a mesh the template is replicated so
        prefill and the pool computation share one device set."""
        if batch not in self._templates:
            t = zoo.make_cache(self.cfg, batch, self.max_seq, **self._cache_kw)
            if self.mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec

                t = jax.device_put(t, NamedSharding(self.mesh, PartitionSpec()))
            self._templates[batch] = t
        return self._templates[batch]

    # -- page accounting ------------------------------------------------------

    def pages_needed(self, rows: int) -> int:
        """Pages covering `rows` cache rows (capped at the view: a windowed
        ring reuses its pages in place once positions wrap)."""
        rows = min(rows, self.view_len)
        return max(1, -(-rows // self.page))

    @property
    def n_free_pages(self) -> int:
        if not self.paged:
            return 1 << 62
        return sum(len(d) for d in self._free_pages)

    @property
    def n_alloc_pages(self) -> int:
        """Total allocatable pages (excludes the two reserved pages)."""
        return self.n_pages - paging.N_RESERVED if self.paged else 1 << 62

    @property
    def page_sharded(self) -> bool:
        """True when the shared pool leaves are actually split on their
        page axis.  The paged-attention kernel is a single-device program,
        so the Scheduler defers to the SPMD gather path on a page-sharded
        pool — unless ``KNOBS.paged_attn_sharded`` opted the layout into
        replication (then this is False and the kernel runs everywhere)."""
        if not self.paged or self.specs is None:
            return False
        import jax.sharding

        is_spec = lambda x: isinstance(x, jax.sharding.PartitionSpec)
        roles = jax.tree.leaves(zoo.cache_shard_roles(self.cfg, self.cache))
        specs = jax.tree.leaves(self.specs, is_leaf=is_spec)
        return any(r == "page" and len(s) > 1 and s[1] is not None
                   for r, s in zip(roles, specs))

    def can_admit(self, reserve_rows: int) -> bool:
        """Would a request needing `reserve_rows` cache rows fit right now?"""
        if not self._free:
            return False
        return (not self.paged
                or self.pages_needed(reserve_rows) <= self.n_free_pages)

    def slot_capacity(self, slot: int) -> int:
        """Cache rows reserved for `slot` at insert time."""
        return int(self._slot_cap[slot])

    def pool_bytes(self) -> int:
        """Device bytes held by the pool cache pytree (global, all shards)."""
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(self.cache))

    # -- slot lifecycle -------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    def acquire(self) -> int:
        if not self._free:
            raise RuntimeError("no free slots")
        slot = self._free.pop(0)
        self._observe_occupancy()
        return slot

    def insert(self, slot: int, cache, length: int, row: int = 0,
               reserve: int | None = None) -> None:
        """Write row `row` of a prefilled batch-k stripe cache into `slot`.

        `length` is the row count actually written (true prompt rows);
        `reserve` is the row budget the request may grow to (prompt +
        max_new_tokens) — in paged mode it sizes the page allocation."""
        reserve = length if reserve is None else reserve
        if self.paged:
            n_alloc = self.pages_needed(reserve)
            if n_alloc > self.n_free_pages:
                raise RuntimeError(
                    f"slot {slot}: {n_alloc} pages needed, "
                    f"{self.n_free_pages} free")
            pages = self._pop_pages(n_alloc)
            ids = np.full((self.n_bt,), paging.SCRATCH_PAGE, np.int32)
            bt_row = np.full((self.n_bt,), paging.SENTINEL_PAGE, np.int32)
            ids[:n_alloc] = bt_row[:n_alloc] = pages
            self.cache = self._insert_paged(
                self.cache, cache, slot, row, jnp.asarray(ids),
                jnp.asarray(bt_row), np.int32(n_alloc))
            self._slot_pages[slot] = pages
        else:
            self.cache = self._write_row(self.cache, cache, slot, row)
        # row budget the request may legally grow to; a windowed ring wraps
        # within its pages, so `reserve` (not n_alloc * page) is the bound
        self._slot_cap[slot] = reserve
        self.slot_len[slot] = length
        self._observe_occupancy()

    def release(self, slot: int) -> None:
        """Reset `slot` to pristine state and return it (and, in paged mode,
        its pages — kpos rows back to the sentinel) to the free lists."""
        if self.paged:
            pages = self._slot_pages.pop(slot, [])
            ids = np.full((self.n_bt,), paging.SCRATCH_PAGE, np.int32)
            ids[: len(pages)] = pages
            self.cache = self._release_paged(
                self.cache, slot, jnp.asarray(ids))
            self._push_pages(pages)
        else:
            self.cache = self._write_row(self.cache, self.template(), slot, 0)
        self.slot_len[slot] = 0
        self._slot_cap[slot] = 0
        self._free.append(slot)
        self._observe_occupancy()

    def rollback(self, pos0, keep, n_written: int, undo=None) -> None:
        """Speculative commit/rollback (serve/spec): of the ``n_written``
        candidate rows a verify step wrote per slot starting at ``pos0``
        (B,), keep the accepted ``keep`` (B,) and rewind the rest — kpos
        swept back to the sentinel (paged: rejected rows become exactly
        as unreachable as unwritten ones; the sweep of a row that went to
        the scratch page is redirected there and is a no-op) or restored
        from undo snapshots (sequential verifiers), with every position
        counter rewound to ``pos0 + keep``.

        No page moves: rejected rows sit inside the slot's existing
        reservation, so the (per-shard) free list, ``pool_bytes`` and the
        ``slot_len``/``slot_capacity`` accounting are untouched — the
        caller advances ``slot_len`` by the emitted count it harvests,
        which equals ``keep`` by construction.  One donated dispatch,
        pinned back to the pool layout under a mesh."""
        jit = self._rollback_jits.get(n_written)
        if jit is None:
            cfg = self.cfg

            def rollback_fn(cache, undo, pos0, keep):
                out = zoo.cache_rollback(cfg, cache, undo, pos0, keep,
                                         n_written)
                return self._constrain(out)

            jit = self._rollback_jits[n_written] = jax.jit(
                rollback_fn, donate_argnums=(0,))
        self.cache = jit(self.cache, undo, jnp.asarray(pos0, jnp.int32),
                         jnp.asarray(keep, jnp.int32))
        if self._m_rollbacks is not None:
            self._m_rollbacks.inc()

    def reset_all(self) -> None:
        if self.paged:
            self.cache = zoo.make_cache(
                self.cfg, self.n_slots, self.max_seq, page=self.page,
                n_pages=self.n_pages, **self._cache_kw)
            self._reset_free_pages()
            self._slot_pages = {}
        else:
            self.cache = zoo.make_cache(
                self.cfg, self.n_slots, self.max_seq, **self._cache_kw)
        if self.shardings is not None:
            self.cache = jax.device_put(self.cache, self.shardings)
        self._free = list(range(self.n_slots))
        self.slot_len[:] = 0
        self._slot_cap[:] = 0
        self._observe_occupancy()
