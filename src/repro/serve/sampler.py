"""On-device token sampling for the serving runtime.

Everything here is pure jnp and runs inside the jitted decode chunk —
no per-token host round-trips. Sampling parameters are per-slot vectors
so one fixed-width decode batch can mix greedy and stochastic requests.

Temperature sampling feeds raw scaled logits to `jax.random.categorical`
(which is softmax-invariant); the former `log(softmax(x) + 1e-9)`
round-trip both wasted work and biased low-probability tokens (the +1e-9
floor inflates the tail relative to the true distribution).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    """logits (B, V) -> argmax token ids (B,) int32."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(key, logits: jax.Array, temperature: jax.Array, top_k: jax.Array) -> jax.Array:
    """Per-slot sampling. logits (B, V) float32; temperature (B,) float32
    (<= 0 -> greedy); top_k (B,) int32 (<= 0 -> full vocab).
    Returns token ids (B,) int32."""
    v = logits.shape[-1]
    pick = greedy(logits)

    # per-slot top-k: threshold at each row's k-th largest logit
    k = jnp.clip(top_k, 0, v)
    sorted_desc = jnp.flip(jnp.sort(logits, axis=-1), axis=-1)
    kth = jnp.take_along_axis(sorted_desc, jnp.maximum(k - 1, 0)[:, None], axis=1)
    masked = jnp.where((k[:, None] > 0) & (logits < kth), -jnp.inf, logits)

    t = jnp.maximum(temperature, 1e-6)[:, None]
    drawn = jax.random.categorical(key, masked / t, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0.0, drawn, pick)
