"""On-device token sampling for the serving runtime.

Everything here is pure jnp and runs inside the jitted decode chunk —
no per-token host round-trips. Sampling parameters are per-slot vectors
so one fixed-width decode batch can mix greedy and stochastic requests.

Temperature sampling feeds raw scaled logits to `jax.random.categorical`
(which is softmax-invariant); the former `log(softmax(x) + 1e-9)`
round-trip both wasted work and biased low-probability tokens (the +1e-9
floor inflates the tail relative to the true distribution).

RNG discipline: every draw uses a **per-slot, per-position** key —
``fold_in(fold_in(base, request_seed), token_index)`` via `fold_keys` —
so a request's sampled stream depends only on its own seed and how many
tokens it has generated, never on which slot it landed in, who its
co-residents are, or how many scheduler steps the pool has run.  That
determinism is what lets speculative decoding assert spec == non-spec
token identity on stochastic requests (serve/spec): the verify step can
recompute the exact token the non-speculative path would have drawn at
each position.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    """logits (B, V) -> argmax token ids (B,) int32."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def fold_keys(base_key, seeds: jax.Array, gens: jax.Array) -> jax.Array:
    """Per-slot draw keys: fold `base_key` by request seed, then by the
    token index the slot is about to sample. seeds/gens (B,) int32."""
    def one(s, g):
        return jax.random.fold_in(jax.random.fold_in(base_key, s), g)

    return jax.vmap(one)(seeds, gens)


def mask_logits(logits: jax.Array, top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Per-slot top-k then top-p (nucleus) masking.  logits (B, V) f32
    (already temperature-scaled); top_k (B,) int32 (<= 0 -> full vocab);
    top_p (B,) f32 (<= 0 or >= 1 -> disabled).  Nucleus keeps the smallest
    prefix of the descending distribution whose mass reaches top_p (the
    first token always survives); ties at the cutoff probability are kept.
    """
    v = logits.shape[-1]

    k = jnp.clip(top_k, 0, v)
    sorted_desc = jnp.flip(jnp.sort(logits, axis=-1), axis=-1)
    kth = jnp.take_along_axis(sorted_desc, jnp.maximum(k - 1, 0)[:, None], axis=1)
    masked = jnp.where((k[:, None] > 0) & (logits < kth), -jnp.inf, logits)

    # nucleus on the top-k survivors: -inf rows softmax to exactly 0
    probs = jax.nn.softmax(masked, axis=-1)
    p_desc = jnp.flip(jnp.sort(probs, axis=-1), axis=-1)
    cum = jnp.cumsum(p_desc, axis=-1)
    keep = (cum - p_desc) < top_p[:, None]          # exclusive prefix mass
    cutoff = jnp.min(jnp.where(keep, p_desc, jnp.inf), axis=-1)
    on = (top_p > 0.0) & (top_p < 1.0)
    return jnp.where(on[:, None] & (probs < cutoff[:, None]), -jnp.inf, masked)


def sample(keys, logits: jax.Array, temperature: jax.Array, top_k: jax.Array,
           top_p: jax.Array | None = None) -> jax.Array:
    """Per-slot sampling. keys (B,) per-slot PRNG keys (see `fold_keys`);
    logits (B, V) float32; temperature (B,) float32 (<= 0 -> greedy);
    top_k (B,) int32 (<= 0 -> full vocab); top_p (B,) float32 (<= 0 ->
    disabled). Returns token ids (B,) int32."""
    pick = greedy(logits)
    if top_p is None:
        top_p = jnp.zeros(logits.shape[:1], jnp.float32)
    t = jnp.maximum(temperature, 1e-6)[:, None]
    masked = mask_logits(logits / t, top_k, top_p)
    drawn = jax.vmap(jax.random.categorical)(keys, masked).astype(jnp.int32)
    return jnp.where(temperature > 0.0, drawn, pick)
