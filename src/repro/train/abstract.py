"""Abstract (ShapeDtypeStruct) views of HiNM-pruned models.

The dry-run lowers full-scale models without allocating anything; gyro
permutation is a numeric offline step, but the *shapes* of masks and packed
weights are config-determined, so we can synthesise abstract mask / packed
pytrees directly from each model's hinm_plan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import HiNMConfig, PackedHiNM
from repro.models import module as nn
from repro.models import zoo
from repro.perm.graph import get_container as _get_container
from repro.perm.graph import set_container as _set_container


def _planned_paths(cfg):
    """Yield (container_key, stack_selector, node) for every planned path.

    Nodes come from the compiled PermGraph (tied partners included as
    first-class nodes), in plan order.
    """
    yield from zoo.perm_graph(cfg).instances()


def packed_leaf_shapes(w_shape: tuple[int, ...], hcfg: HiNMConfig, dtype):
    """(…, n_in, n_out) stored weight -> abstract PackedHiNM."""
    n_in, n_out = w_shape[-2], w_shape[-1]
    hcfg.validate_shape(n_out, n_in)
    t = n_out // hcfg.v
    k = hcfg.kept_columns(n_in)
    kn = k // hcfg.m * hcfg.n
    lead = tuple(w_shape[:-2])
    return PackedHiNM(
        vals=jax.ShapeDtypeStruct(lead + (t, hcfg.v, kn), dtype),
        vec_idx=jax.ShapeDtypeStruct(lead + (t, k), jnp.int32),
        nm_idx=jax.ShapeDtypeStruct(lead + (t, hcfg.v, kn), jnp.int8),
        n_out=n_out,
        n_in=n_in,
        config=hcfg,
    )


def abstract_masks(params_shape, cfg):
    """Mask pytree of ShapeDtypeStructs (bool) over planned projections;
    None everywhere else. Mirrors prune_model's mask output structure."""
    masks = jax.tree.map(lambda x: None, params_shape,
                         is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
    masks = dict(masks) if isinstance(masks, dict) else masks
    for key, sel, spec in _planned_paths(cfg):
        container = _get_container(params_shape, key, sel)
        node = nn.get_path(container, spec.path)
        mcontainer = _get_container(masks, key, sel)
        mnode = {k: None for k in node}
        mnode["w"] = jax.ShapeDtypeStruct(node["w"].shape, jnp.bool_)
        mcontainer = nn.set_path(mcontainer, spec.path, mnode)
        masks = _set_container(masks, key, sel, mcontainer)
    return masks


def abstract_packed(params_shape, cfg):
    """Params pytree with planned weights replaced by abstract PackedHiNM."""
    packed = params_shape
    for key, sel, spec in _planned_paths(cfg):
        container = _get_container(packed, key, sel)
        node = dict(nn.get_path(container, spec.path))
        node["w"] = packed_leaf_shapes(tuple(node["w"].shape), cfg.hinm, cfg.dtype)
        container = nn.set_path(container, spec.path, node)
        packed = _set_container(packed, key, sel, container)
    return packed
