"""Model-level HiNM pruning walker.

Applies each model's `hinm_plan` to its params:
  - runs gyro-permutation (or a baseline method) per prunable projection,
  - PHYSICALLY applies row permutations to producer weights/biases and the
    matching column permutations to consumers (so the pruned model computes
    the same function — the paper's offline pre-ordering),
  - returns (permuted params, keep-mask pytree, packed pytree, report).

Plan ordering invariant: producers appear before their consumers within a
layer's spec list, so every projection is packed from its final (fully
permuted) values. Tied partners (SwiGLU up-proj) share the producer's row
perm and are pruned immediately after it with identity OCP.

Handles scan-stacked layer params (leading L axis), per-pattern-position
stacks (hybrid/ssm), enc/dec stacks, MoE expert stacks (leading E axis)
and GQA consumer expansion ("path:gqa").

Weights are stored (n_in, n_out); HiNM rows = stored columns, so the walker
transposes in and out of the core API. Returned masks align with the
RETURNED (permuted) params, not the originals.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing, sparsity
from repro.core.gyro import gyro_permute
from repro.core.types import HiNMConfig
from repro.models import module as nn
from repro.models import zoo


@dataclasses.dataclass
class PruneReport:
    per_layer: list[tuple[str, float]] = dataclasses.field(default_factory=list)

    @property
    def mean_retained(self) -> float:
        if not self.per_layer:
            return 1.0
        return float(np.mean([r for _, r in self.per_layer]))


def _gqa_expand_perm(perm_v: np.ndarray, n_kv: int, n_heads: int, hd: int) -> np.ndarray:
    """Expand a (KV*hd) within-kv-head row perm to the (H*hd) wo-column perm."""
    g = n_heads // n_kv
    out = np.empty(n_heads * hd, dtype=np.int64)
    for h in range(n_heads):
        kv = h // g
        local = perm_v[kv * hd : (kv + 1) * hd] - kv * hd
        out[h * hd : (h + 1) * hd] = h * hd + local
    return out


def _search(
    sal: np.ndarray,
    sal_rows: np.ndarray,
    hcfg: HiNMConfig,
    can_permute_rows: bool,
    row_blocks: int,
    method: str,
    rng: np.random.Generator,
    ocp_iters: int,
    icp_iters: int,
):
    """Permutation search on (n_out, n_in) saliency. Returns (perm, col_order)."""
    n_out = sal.shape[0]
    run_ocp = can_permute_rows and method in ("gyro", "ocp_only", "v1", "v2")
    run_icp = method in ("gyro", "icp_only", "v1", "v2")

    if run_ocp:
        padded = np.pad(sal_rows, ((0, 0), (0, (-sal_rows.shape[1]) % hcfg.m)))
        if row_blocks > 1:
            bs = n_out // row_blocks
            perms = []
            for b in range(row_blocks):
                res = gyro_permute(padded[b * bs : (b + 1) * bs], hcfg,
                                   ocp_iters=ocp_iters, rng=rng, run_icp=False)
                perms.append(res.out_perm + b * bs)
            out_perm = np.concatenate(perms)
        else:
            res = gyro_permute(padded, hcfg, ocp_iters=ocp_iters, rng=rng, run_icp=False)
            out_perm = res.out_perm
    else:
        out_perm = np.arange(n_out)

    res2 = gyro_permute(sal[out_perm], hcfg, icp_iters=icp_iters, rng=rng,
                        run_ocp=False, run_icp=run_icp)
    return out_perm, res2.col_order


def _saliency(w_t: jnp.ndarray, fisher_t, saliency_kind: str) -> np.ndarray:
    if saliency_kind == "second_order" and fisher_t is not None:
        return np.asarray((w_t.astype(jnp.float32) ** 2) * fisher_t, np.float32)
    return np.asarray(jnp.abs(w_t), np.float32)


def _pack_and_mask(w, col_order, out_perm, hcfg):
    """Pack an (n_in, n_out) stored weight given search results.

    Returns (w_permuted, mask aligned to w_permuted, packed)."""
    wt = jnp.asarray(w).T
    w_p = wt[jnp.asarray(out_perm)]
    sal_p = jnp.abs(w_p.astype(jnp.float32))
    col = jnp.asarray(col_order)
    packed = packing.pack(w_p, hcfg, col_ids=col, sal=sal_p)
    mask_p = sparsity.hinm_mask_from_columns(sal_p, col, hcfg)
    # nm selection inside pack uses the same saliency -> identical support
    retained = float(jnp.sum(sal_p * mask_p) / jnp.maximum(sal_p.sum(), 1e-30))
    return w_p.T, mask_p.T, packed, retained


def _prune_layer_dict(
    layer: dict,
    specs: list,
    cfg,
    method: str,
    rng: np.random.Generator,
    fisher_layer: dict | None,
    saliency_kind: str,
    ocp_iters: int,
    icp_iters: int,
    report: PruneReport,
    tag: str,
):
    """Prune one (unstacked) layer dict. Returns (new_layer, masks, packed)."""
    hcfg: HiNMConfig = cfg.hinm
    masks: dict[str, jnp.ndarray] = {}   # path -> mask (stored orientation)
    packs: dict[str, object] = {}        # path -> PackedHiNM (or expert list)

    def fisher_t(path, e=None):
        if fisher_layer is None or saliency_kind != "second_order":
            return None
        f = nn.get_path(fisher_layer, path)["w"]
        f = f if e is None else f[e]
        return jnp.asarray(f).T

    def prune_path(path, can_rows, row_blocks, tied_paths=(), forced_perm=None):
        """Search + pack one path (handles MoE expert stacking)."""
        node = nn.get_path(layer, path)
        w = node["w"]

        def one(wi, fi, tws, fperm):
            wt = jnp.asarray(wi).T
            sal = _saliency(wt, fi, saliency_kind)
            sal_rows = sal
            for tw in tws:
                sal_rows = np.concatenate(
                    [sal_rows, _saliency(jnp.asarray(tw).T, None, "magnitude")], axis=1
                )
            if fperm is not None:
                perm = fperm
                _, col_order = _search(sal[perm], sal, hcfg, False, 1, method, rng, 0, icp_iters)
            else:
                perm, col_order = _search(
                    sal, sal_rows, hcfg, can_rows, row_blocks, method, rng,
                    ocp_iters, icp_iters,
                )
            return (perm,) + _pack_and_mask(wi, col_order, perm, hcfg)

        if w.ndim == 3:  # expert stack
            tied_ws = [nn.get_path(layer, t)["w"] for t in tied_paths]
            outs = [
                one(w[e], fisher_t(path, e), [tw[e] for tw in tied_ws],
                    None if forced_perm is None else forced_perm[e])
                for e in range(w.shape[0])
            ]
            perm = np.stack([o[0] for o in outs])
            new_w = jnp.stack([o[1] for o in outs])
            mask = jnp.stack([o[2] for o in outs])
            packed = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[3] for o in outs])
            retained = float(np.mean([o[4] for o in outs]))
        else:
            tied_ws = [nn.get_path(layer, t)["w"] for t in tied_paths]
            perm, new_w, mask, packed, retained = one(
                w, fisher_t(path), tied_ws, forced_perm
            )
        report.per_layer.append((f"{tag}/{path}", retained))
        return perm, new_w, mask, packed

    def permute_cols(w, perm):
        """Permute stored n_out axis (axis -1) — producer row perm."""
        if w.ndim == 3:
            return jnp.stack([jnp.take(w[e], jnp.asarray(perm[e]), axis=1)
                              for e in range(w.shape[0])])
        return jnp.take(w, jnp.asarray(perm), axis=1)

    def permute_bias(b, perm):
        if b.ndim == 2:
            return jnp.stack([jnp.take(b[e], jnp.asarray(perm[e]))
                              for e in range(b.shape[0])])
        return jnp.take(b, jnp.asarray(perm))

    def permute_rows(w, perm):
        """Permute stored n_in axis — consumer column perm."""
        if w.ndim == 3:
            p = perm if perm.ndim == 2 else np.broadcast_to(perm, (w.shape[0],) + perm.shape)
            return jnp.stack([jnp.take(w[e], jnp.asarray(p[e]), axis=0)
                              for e in range(w.shape[0])])
        return jnp.take(w, jnp.asarray(perm), axis=0)

    def is_identity(perm):
        if perm.ndim == 2:
            return all(np.array_equal(p, np.arange(p.shape[0])) for p in perm)
        return np.array_equal(perm, np.arange(perm.shape[0]))

    for spec in specs:
        perm, new_w, mask, packed = prune_path(
            spec.path, spec.can_permute_rows, spec.row_blocks, spec.tied
        )
        node = dict(nn.get_path(layer, spec.path))
        node["w"] = new_w
        if "b" in node and node["b"] is not None and not is_identity(perm):
            node["b"] = permute_bias(node["b"], perm)
        layer = nn.set_path(layer, spec.path, node)
        masks[spec.path] = mask
        packs[spec.path] = packed

        if not is_identity(perm):
            # tied partners share the row perm; consumers fold it into cols
            for t in spec.tied:
                tn = dict(nn.get_path(layer, t))
                tn["w"] = permute_cols(tn["w"], perm)
                if "b" in tn and tn["b"] is not None:
                    tn["b"] = permute_bias(tn["b"], perm)
                layer = nn.set_path(layer, t, tn)
            for cons in spec.consumers:
                cpath, _, mode = cons.partition(":")
                if mode == "gqa":
                    cperm = _gqa_expand_perm(perm, cfg.n_kv_heads, cfg.n_heads, cfg.head_dim)
                else:
                    cperm = perm
                cn = dict(nn.get_path(layer, cpath))
                cn["w"] = permute_rows(cn["w"], cperm)
                layer = nn.set_path(layer, cpath, cn)

        # tied partners get their own ICP/pack now (identity OCP, rows fixed)
        for t in spec.tied:
            _, tw, tmask, tpacked = prune_path(t, False, 1, (), forced_perm=None)
            tn = dict(nn.get_path(layer, t))
            tn["w"] = tw
            layer = nn.set_path(layer, t, tn)
            masks[t] = tmask
            packs[t] = tpacked

    # assemble mask / packed pytrees mirroring the (permuted) layer
    mask_tree = jax.tree.map(lambda x: None, layer,
                             is_leaf=lambda x: not isinstance(x, dict))
    packed_tree = layer
    for path, m in masks.items():
        node = nn.get_path(layer, path)
        mask_tree = nn.set_path(
            mask_tree, path, {k: (m if k == "w" else None) for k in node}
        )
    for path, p in packs.items():
        node = dict(nn.get_path(layer, path))
        node["w"] = p
        packed_tree = nn.set_path(packed_tree, path, node)
    return layer, mask_tree, packed_tree


def _map_stacked(layer_stack, fn, n: int):
    """Apply fn to each unstacked layer of a stacked tree; restack results."""
    outs = [fn(jax.tree.map(lambda a: a[i], layer_stack), i) for i in range(n)]
    restacked = []
    for j in range(len(outs[0])):
        restacked.append(
            jax.tree.map(
                lambda *xs: None if xs[0] is None else jnp.stack(xs),
                *[o[j] for o in outs],
                is_leaf=lambda x: x is None,
            )
        )
    return restacked


def prune_model(
    params,
    cfg,
    method: str = "gyro",
    rng: np.random.Generator | None = None,
    fisher=None,
    saliency_kind: str = "magnitude",
    ocp_iters: int = 8,
    icp_iters: int = 8,
    permute_params: bool = True,
):
    """Prune every planned projection. Returns (params', masks, packed, report).

    `permute_params=False` runs the same gyro search but returns masks in
    the ORIGINAL layout without touching params (tiles become
    non-contiguous row sets — irrelevant for masked-dense training, and it
    keeps optimizer moments aligned when refreshing masks mid-training).
    Packing for serving requires the physical layout (`True`, default).
    """
    rng = rng or np.random.default_rng(0)
    plan = zoo.hinm_plan(cfg)
    report = PruneReport()
    if not permute_params:
        return _prune_virtual(params, cfg, method, rng, fisher, saliency_kind,
                              ocp_iters, icp_iters, report)

    def prune_stack(stack, specs, fstack, tag):
        n = jax.tree.leaves(stack)[0].shape[0]

        def fn(layer, i):
            fl = None if fstack is None else jax.tree.map(lambda a: a[i], fstack)
            return _prune_layer_dict(
                layer, specs, cfg, method, rng, fl, saliency_kind,
                ocp_iters, icp_iters, report, f"{tag}[{i}]",
            )

        return _map_stacked(stack, fn, n)

    def none_like(tree):
        return jax.tree.map(lambda x: None, tree,
                            is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))

    new_params = dict(params)
    masks = dict(none_like(params))
    packed = dict(params)
    if isinstance(plan, dict) and "enc" in plan:
        fe = None if fisher is None else fisher["enc"]
        fd = None if fisher is None else fisher["dec"]
        enc_p, enc_m, enc_k = prune_stack(params["enc"], plan["enc"], fe, "enc")
        dec_p, dec_m, dec_k = prune_stack(params["dec"], plan["dec"], fd, "dec")
        new_params.update(enc=enc_p, dec=dec_p)
        masks.update(enc=enc_m, dec=dec_m)
        packed.update(enc=enc_k, dec=dec_k)
    elif isinstance(plan, dict):  # per-pattern-position stacks
        ps, ms, ks = list(params["stacks"]), [], []
        for j, specs in plan.items():
            fj = None if fisher is None else fisher["stacks"][j]
            p, m, k = prune_stack(params["stacks"][j], specs, fj, f"stack{j}")
            ps[j] = p
            ms.append(m)
            ks.append(k)
        new_params["stacks"] = ps
        masks["stacks"] = ms
        packed["stacks"] = ks
    else:
        fb = None if fisher is None else fisher["blocks"]
        blk_p, blk_m, blk_k = prune_stack(params["blocks"], plan, fb, "blocks")
        new_params["blocks"] = blk_p
        masks["blocks"] = blk_m
        packed["blocks"] = blk_k
    # non-pruned top-level entries of packed keep the permuted params
    for key in new_params:
        if key not in ("blocks", "stacks", "enc", "dec"):
            packed[key] = new_params[key]
    return new_params, masks, packed, report


def _prune_virtual(params, cfg, method, rng, fisher, saliency_kind,
                   ocp_iters, icp_iters, report):
    """Mask-only pruning: gyro search per projection, mask mapped back to
    the original row order; params untouched, no packing."""
    from repro.train.abstract import _get_container, _planned_paths, _set_container

    hcfg = cfg.hinm
    masks = jax.tree.map(lambda x: None, params,
                         is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
    masks = dict(masks)
    for key, sel, spec in _planned_paths(cfg):
        container = _get_container(params, key, sel)
        node = nn.get_path(container, spec.path)
        w = node["w"]

        def one(wi):
            wt = jnp.asarray(wi).T
            sal = _saliency(wt, None, "magnitude")
            perm, col_order = _search(sal, sal, hcfg, spec.can_permute_rows,
                                      spec.row_blocks, method, rng,
                                      ocp_iters, icp_iters)
            _, mask_p, _, retained = _pack_and_mask(wi, col_order, perm, hcfg)
            inv = np.argsort(perm)
            return jnp.take(mask_p, jnp.asarray(inv), axis=1), retained

        lead = w.ndim - 2
        if lead == 0:
            mask, retained = one(w)
        else:
            flat = w.reshape((-1,) + w.shape[-2:])
            outs = [one(flat[i]) for i in range(flat.shape[0])]
            mask = jnp.stack([o[0] for o in outs]).reshape(w.shape)
            retained = float(np.mean([o[1] for o in outs]))
        report.per_layer.append((f"{key}/{spec.path}", retained))
        mcontainer = _get_container(masks, key, sel)
        mcontainer = nn.set_path(mcontainer, spec.path,
                                 {k: (mask if k == "w" else None) for k in node})
        masks = _set_container(masks, key, sel, mcontainer)
    return params, masks, None, report


def apply_masks(params, masks):
    """Elementwise multiply where a mask exists (masked-dense training)."""

    def f(p, m):
        if m is None:
            return p
        return p * m.astype(p.dtype)

    return jax.tree.map(f, params, masks, is_leaf=lambda x: x is None)
