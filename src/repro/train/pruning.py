"""Model-level HiNM pruning on the PermGraph engine.

The model's `hinm_plan` compiles into a permutation-propagation graph
(`repro.perm`): prunable projections are nodes, the coupling rules that
used to be hardcoded walker special cases (GQA expansion, MoE expert
stacks, tied SwiGLU partners, enc/dec stacks) are typed edges. Pruning runs
in three phases — search (gyro per node, thread-pool dispatched over
independent nodes across all layers), propagate (fold every out-perm along
its edges, with bijection/identity/block validation), realize (pack + mask
+ report, shared with `core.api.prune_matrix`).

Weights are stored (n_in, n_out); HiNM rows = stored columns, so the engine
transposes in and out of the core API. Returned masks align with the
RETURNED (permuted) params, not the originals.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.perm import PermCache, ModelPermEngine
from repro.perm.engine import PruneReport
from repro.perm.graph import get_container
from repro.perm.propagate import gqa_expand_perm as _gqa_expand_perm  # noqa: F401 (public via tests)

__all__ = ["PruneReport", "prune_model", "apply_masks", "_gqa_expand_perm"]


def prune_model(
    params,
    cfg,
    method: str = "gyro",
    rng: np.random.Generator | None = None,
    fisher=None,
    saliency_kind: str = "magnitude",
    ocp_iters: int = 8,
    icp_iters: int = 8,
    permute_params: bool = True,
    cache: PermCache | None = None,
    workers: int | None = None,
):
    """Prune every planned projection. Returns (params', masks, packed, report).

    `permute_params=False` runs the same gyro search but returns masks in
    the ORIGINAL layout without touching params (tiles become
    non-contiguous row sets — irrelevant for masked-dense training, and it
    keeps optimizer moments aligned when refreshing masks mid-training).
    Packing for serving requires the physical layout (`True`, default).

    `cache` (a PermCache) skips searches whose saliency matrices hash to a
    previously solved instance — repeated gradual-pruning refreshes hit it.
    `workers` caps the search thread pool (default REPRO_PERM_WORKERS or
    cpu count; 1 = serial).
    """
    engine = ModelPermEngine(
        cfg, method=method, rng=rng or np.random.default_rng(0),
        fisher=fisher, saliency_kind=saliency_kind,
        ocp_iters=ocp_iters, icp_iters=icp_iters,
        cache=cache, workers=workers,
    )
    if not permute_params:
        masks = engine.run_virtual(params)
        return params, masks, None, engine.report

    stacked = {}
    for ci, c in enumerate(engine.graph.containers):
        fstack = None if fisher is None else get_container(fisher, c.key, c.sel)
        stacked[ci] = (get_container(params, c.key, c.sel), fstack)
    results = engine.run_stacks(stacked)

    def none_like(tree):
        return jax.tree.map(lambda x: None, tree,
                            is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))

    new_params = dict(params)
    masks = dict(none_like(params))
    packed = dict(params)
    stacks_p = stacks_m = stacks_k = None
    for ci, c in enumerate(engine.graph.containers):
        p, m, k = results[ci]
        if c.sel is not None:  # per-pattern-position stacks
            if stacks_p is None:
                stacks_p = list(params[c.key])
                stacks_m, stacks_k = [None] * len(stacks_p), [None] * len(stacks_p)
            stacks_p[c.sel], stacks_m[c.sel], stacks_k[c.sel] = p, m, k
            new_params[c.key], masks[c.key], packed[c.key] = (
                stacks_p, stacks_m, stacks_k)
        else:
            new_params[c.key], masks[c.key], packed[c.key] = p, m, k
    # non-pruned top-level entries of packed keep the (permuted) params
    pruned_keys = {c.key for c in engine.graph.containers}
    for key in new_params:
        if key not in pruned_keys:
            packed[key] = new_params[key]
    return new_params, masks, packed, engine.report


def apply_masks(params, masks):
    """Elementwise multiply where a mask exists (masked-dense training)."""

    def f(p, m):
        if m is None:
            return p
        return p * m.astype(p.dtype)

    return jax.tree.map(f, params, masks, is_leaf=lambda x: x is None)
