"""Fault-tolerant training loop.

Production behaviours implemented (and exercised by tests):
  - resume-from-latest on startup (elastic: restored arrays are re-placed
    with the current mesh's shardings, so the device count may change
    between runs);
  - periodic async checkpointing (overlaps I/O with compute);
  - per-step retry: a transient failure re-runs the step once; a second
    failure restores the last checkpoint and SKIPS the offending batch
    (data-skip is the standard poison-batch mitigation);
  - straggler detection: a rolling P50 step-time estimate flags steps
    slower than `straggler_factor` x median. In a single-controller JAX
    job the mitigation hook logs and (optionally) triggers a checkpoint so
    an external orchestrator can reschedule the slice — the hook point is
    `on_straggler`;
  - gradual HiNM pruning via a schedule callback that swaps the mask
    pytree at pruning events (see train/gradual.py).

The loop is deliberately host-driven and framework-agnostic: step_fn is
any jit'd callable from train/steps.py.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    straggler_factor: float = 3.0
    max_retries: int = 1
    log_every: int = 10


@dataclasses.dataclass
class LoopState:
    params: Any
    opt_state: Any
    masks: Any
    step: int = 0
    comp_error: Any = None


def run(
    state: LoopState,
    step_fn: Callable,
    batch_iter,
    cfg: LoopConfig,
    on_step: Callable[[int, dict], None] | None = None,
    on_straggler: Callable[[int, float], None] | None = None,
    mask_schedule: Callable[[int, LoopState], Any] | None = None,
    fail_injector: Callable[[int], None] | None = None,
) -> LoopState:
    mgr = CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep_checkpoints)

    # ---- elastic resume
    restorable = {"params": state.params, "opt_state": state.opt_state,
                  "masks": state.masks}
    restored, ckpt_step = mgr.restore_latest(restorable)
    if restored is not None:
        state.params = restored["params"]
        state.opt_state = restored["opt_state"]
        state.masks = restored["masks"]
        state.step = ckpt_step + 1
        log.info("resumed from checkpoint at step %d", ckpt_step)

    times: list[float] = []
    it = iter(batch_iter)
    consumed = state.step  # deterministic pipeline: skip consumed batches
    for _ in range(consumed):
        next(it)

    while state.step < cfg.total_steps:
        batch = next(it)
        if mask_schedule is not None:
            new_masks = mask_schedule(state.step, state)
            if new_masks is not None:
                state.masks = new_masks
        t0 = time.time()
        attempt = 0
        while True:
            try:
                if fail_injector is not None:
                    fail_injector(state.step)
                out = step_fn(state.params, state.opt_state, state.masks,
                              batch, state.step, state.comp_error)
                state.params, state.opt_state, metrics = out[0], out[1], out[2]
                state.comp_error = out[3] if len(out) > 3 else None
                break
            except Exception as e:  # noqa: BLE001
                attempt += 1
                log.warning("step %d failed (attempt %d): %r", state.step, attempt, e)
                if attempt <= cfg.max_retries:
                    continue
                # restore-and-skip: reload last checkpoint, skip this batch
                restored, ckpt_step = mgr.restore_latest(restorable)
                if restored is not None:
                    state.params = restored["params"]
                    state.opt_state = restored["opt_state"]
                    state.masks = restored["masks"]
                    log.warning("restored step-%d checkpoint; skipping batch %d",
                                ckpt_step, state.step)
                metrics = {"loss": float("nan"), "skipped": True}
                break

        dt = time.time() - t0
        if times and dt > cfg.straggler_factor * float(np.median(times)):
            log.warning("straggler: step %d took %.2fs (median %.2fs)",
                        state.step, dt, float(np.median(times)))
            if on_straggler is not None:
                on_straggler(state.step, dt)
        times.append(dt)
        if len(times) > 50:
            times.pop(0)

        if on_step is not None:
            on_step(state.step, {k: (float(v) if hasattr(v, "item") else v)
                                 for k, v in metrics.items()})
        if state.step % cfg.log_every == 0:
            loss = metrics.get("loss")
            log.info("step %d loss %.4f (%.2fs)", state.step,
                     float(loss) if loss is not None else float("nan"), dt)
        if state.step > 0 and state.step % cfg.checkpoint_every == 0:
            mgr.save_async({"params": state.params, "opt_state": state.opt_state,
                            "masks": state.masks}, state.step)
        state.step += 1

    mgr.save_async({"params": state.params, "opt_state": state.opt_state,
                    "masks": state.masks}, state.step - 1)
    mgr.wait()
    return state
