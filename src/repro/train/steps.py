"""jit-compiled train / serve step builders with explicit shardings.

`make_train_step(cfg, mesh, ...)` returns (step_fn, shardings) where
step_fn(params, opt_state, masks, batch, step) -> (params, opt_state, metrics).
The cross-entropy is computed in sequence chunks so the (B, S, vocab)
logits tensor never materialises (vocab stays TP-sharded inside each chunk).

HiNM integration: masks (same pytree as params, None on unpruned leaves)
are applied to the params before the forward pass AND re-applied to the
updated params, implementing masked-dense sparse training; gradients flow
only through surviving weights (straight-through on the mask support).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import zoo
from repro.optim import clip_by_global_norm, make_optimizer
from repro.optim.compression import ef_topk_compress
from repro.train.pruning import apply_masks

XENT_CHUNK = 512


def chunked_xent(params, cfg, x: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy, scanning over sequence chunks."""
    from repro.models import probe_mode

    b, s, d = x.shape
    chunk = s if probe_mode.enabled() else min(XENT_CHUNK, s)
    if s % chunk:
        chunk = s
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, d).swapaxes(0, 1)          # (nc, B, c, D)
    lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    def body(carry, t):
        xt, lt = t
        logits = zoo.logits_fn(params, cfg, xt).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction keeps the vocab dim sharded (a take_along_axis
        # here would force an all-gather of the full logits chunk)
        onehot = jax.nn.one_hot(lt, logits.shape[-1], dtype=jnp.float32)
        gold = jnp.einsum("bcv,bcv->bc", logits, onehot)
        # small z-loss for stability at scale
        loss = (logz - gold) + 1e-4 * logz**2
        return carry + loss.sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (b * s)


def make_train_step(
    cfg,
    mesh,
    optimizer_name: str = "adamw",
    lr_fn=None,
    grad_clip: float = 1.0,
    compress_kfrac: float = 0.0,
    microbatches: int = 1,
):
    """Build the pjit'd train step + its shardings (abstract, no allocation).

    `microbatches` > 1 runs gradient accumulation: the remat'd per-layer
    activation stack shrinks by the same factor (the lever that fits the
    large train_4k cells into HBM; grads are accumulated in f32)."""
    opt = make_optimizer(optimizer_name)
    lr_fn = lr_fn or (lambda step: 3e-4)

    def loss_fn(params, masks, batch):
        p = apply_masks(params, masks)
        x = zoo.forward(p, cfg, batch["tokens"], embeds=batch.get("embeds"))
        return chunked_xent(p, cfg, x, batch["labels"])

    def grads_of(params, masks, batch):
        if microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, masks, batch)

        def mb_slice(x):
            return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

        batch_mb = jax.tree.map(mb_slice, batch)
        # accumulate in f32 when params are narrow; for very large models
        # (adafactor configs) accumulate in param dtype to halve the buffer
        acc_dt = (lambda p: p.dtype) if optimizer_name == "adafactor" else (
            lambda p: jnp.float32
        )
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt(p)), params)

        def accum(carry, mbatch):
            g_acc, l_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, masks, mbatch)
            g_acc = jax.tree.map(
                lambda a, g: a + (g / microbatches).astype(a.dtype), g_acc, grads
            )
            return (g_acc, l_acc + loss / microbatches), None

        (grads, loss), _ = jax.lax.scan(accum, (zeros, jnp.zeros((), jnp.float32)), batch_mb)
        return loss, grads

    def step_fn(params, opt_state, masks, batch, step, comp_error=None):
        loss, grads = grads_of(params, masks, batch)
        if compress_kfrac > 0.0 and comp_error is not None:
            grads, comp_error = ef_topk_compress(grads, comp_error, compress_kfrac)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        new_params, new_opt = opt.update(grads, opt_state, params, lr_fn(step))
        new_params = apply_masks(new_params, masks)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr_fn(step)}
        return new_params, new_opt, metrics, comp_error

    return step_fn, opt


def shard_train_step(step_fn, cfg, mesh, params_shape, opt_shape, masks_shape,
                     batch_shape, donate: bool = True, with_compression: bool = False):
    """Wrap step_fn in jax.jit with explicit in/out shardings for `mesh`."""
    pspecs = shd.param_specs(params_shape, mesh, cfg)
    ospecs = shd.opt_state_specs(opt_shape, pspecs)
    mspecs = jax.tree.map(
        lambda m, s: s if m is not None else None,
        masks_shape, pspecs, is_leaf=lambda x: x is None,
    )
    bspecs = shd.batch_specs(batch_shape, mesh)
    espec = pspecs if with_compression else None
    in_specs = (pspecs, ospecs, mspecs, bspecs, P(), espec)
    out_specs = (pspecs, ospecs, P(), espec)

    def named(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s) if s is not None else None,
            tree,
            is_leaf=lambda x: isinstance(x, P) or x is None,
        )

    jitted = jax.jit(
        step_fn,
        in_shardings=named(in_specs),
        out_shardings=named(out_specs),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, in_specs, out_specs


def make_serve_steps(cfg, mesh):
    """Build (prefill_fn, decode_fn) with cache/batch shardings resolved."""

    def prefill_fn(params, tokens, cache, embeds=None):
        last_x, cache = zoo.prefill(params, cfg, tokens, cache, embeds=embeds)
        logits = zoo.logits_fn(params, cfg, last_x)
        return logits, cache

    def decode_fn(params, tokens, cache):
        return zoo.decode_step(params, cfg, tokens, cache)

    return prefill_fn, decode_fn


def shard_serve_step(decode_fn, cfg, mesh, params_shape, cache_shape, batch: int):
    pspecs = shd.param_specs(params_shape, mesh, cfg)
    cspecs = shd.cache_specs(cache_shape, mesh, cfg)
    tok_shape = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    tok_spec = shd.batch_specs({"t": tok_shape}, mesh)["t"]
    dp = tuple(tok_spec)[0]  # None when the batch doesn't divide (B=1 decode)

    def named(tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    jitted = jax.jit(
        decode_fn,
        in_shardings=(named(pspecs), named(tok_spec), named(cspecs)),
        out_shardings=(named(P(dp, "model")), named(cspecs)),
        donate_argnums=(2,),
    )
    return jitted, pspecs, cspecs
