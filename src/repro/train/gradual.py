"""Gradual HiNM pruning schedule (paper §5.1.2).

The paper's gradual recipe: ramp COLUMN-VECTOR sparsity first (cubic ramp,
as in Zhu & Gupta 2018), and only once the target vector sparsity is
reached, switch on the N:M stage. Permutations are refreshed from current
saliency at a configurable cadence (each refresh runs the full gyro search
and physically re-permutes the params; between refreshes only the masks
are recomputed for the fixed layout, which is cheap and jit-friendly).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparsity
from repro.core.types import HiNMConfig
from repro.models import module as nn
from repro.models import zoo
from repro.train import pruning


@dataclasses.dataclass
class GradualSchedule:
    target: HiNMConfig
    start_step: int = 0
    vector_end_step: int = 100     # vector ramp completes here
    nm_step: int = 150             # N:M stage switches on here
    update_every: int = 10         # mask recompute cadence
    refresh_perm_every: int = 0    # 0 = permute once at nm_step

    def vector_sparsity(self, step: int) -> float:
        t = np.clip((step - self.start_step)
                    / max(self.vector_end_step - self.start_step, 1), 0.0, 1.0)
        return float(self.target.vector_sparsity * (1 - (1 - t) ** 3))

    def nm_active(self, step: int) -> bool:
        return step >= self.nm_step

    def config_at(self, step: int) -> HiNMConfig:
        return HiNMConfig(
            v=self.target.v, n=self.target.n, m=self.target.m,
            vector_sparsity=self.vector_sparsity(step),
        )


def _mask_for_weight(w, hcfg: HiNMConfig, nm_on: bool):
    """Keep-mask for one stored (n_in, n_out) weight, current layout."""
    sal = jnp.abs(w.astype(jnp.float32)).T          # (n_out, n_in)
    if hcfg.vector_sparsity <= 0.0 and not nm_on:
        return jnp.ones_like(w, dtype=bool)
    if not nm_on:
        mask = sparsity.vector_mask(sal, hcfg)
    else:
        mask = sparsity.hinm_mask(sal, hcfg)
    return mask.T


def recompute_masks(params, cfg, hcfg: HiNMConfig, nm_on: bool):
    """Recompute masks for the *current* layout (no permutation search).

    Walks the model plan; handles stacked layers and expert stacks by
    vmapping the single-matrix mask function.
    """
    from repro.train.abstract import _planned_paths, _get_container, _set_container

    masks = jax.tree.map(lambda x: None, params,
                         is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
    masks = dict(masks)
    for key, sel, spec in _planned_paths(cfg):
        container = _get_container(params, key, sel)
        node = nn.get_path(container, spec.path)
        w = node["w"]
        fn = lambda wi: _mask_for_weight(wi, hcfg, nm_on)
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)
        mask = fn(w)
        mcontainer = _get_container(masks, key, sel)
        mcontainer = nn.set_path(mcontainer, spec.path,
                                 {k: (mask if k == "w" else None) for k in node})
        masks = _set_container(masks, key, sel, mcontainer)
    return masks


def make_mask_schedule(cfg, sched: GradualSchedule, method: str = "gyro"):
    """Returns a callback for train.loop.run(mask_schedule=...).

    At each `update_every` step the masks are recomputed from the live
    weights at the scheduled sparsity; at `nm_step` (and every
    `refresh_perm_every` if nonzero) the full gyro permutation re-runs and
    the params are physically re-permuted in the loop state. Refreshes
    share a saliency-hash PermCache, so a refresh over weights whose
    saliency hasn't changed (resumed runs, frozen layers, repeated
    schedule hits) skips the redundant gyro searches.
    """
    from repro.perm import PermCache

    state_cache = {"last": -1}
    perm_cache = PermCache()

    def schedule(step: int, loop_state):
        due = (step % sched.update_every == 0) or step == sched.nm_step
        if not due or step == state_cache["last"]:
            return None
        refresh = (step == sched.nm_step) or (
            sched.refresh_perm_every
            and sched.nm_active(step)
            and step % sched.refresh_perm_every == 0
        )
        # after the N:M switch the mask layout is frozen (a plain recompute
        # would fall back to the identity layout and discard the gyro
        # permutation); only explicit perm refreshes update it
        if sched.nm_active(step) and not refresh and step > sched.nm_step:
            return None
        state_cache["last"] = step
        hcfg = sched.config_at(step)
        nm_on = sched.nm_active(step)
        if refresh and method != "noperm":
            # virtual mode: masks in the original layout, params untouched —
            # optimizer moments stay aligned across the refresh
            _, masks, _, _ = pruning.prune_model(
                loop_state.params, cfg, method=method,
                rng=np.random.default_rng(step), permute_params=False,
                cache=perm_cache,
            )
            return masks
        if hcfg.vector_sparsity <= 0.0 and not nm_on:
            return None
        return recompute_masks(loop_state.params, cfg, hcfg, nm_on)

    return schedule
