"""Deterministic synthetic LM data pipeline.

Produces a structured, learnable token stream (a mixture of order-2 Markov
chains with per-sequence regime switching) rather than iid noise, so small
training runs exhibit a real, monotonically decreasing loss and HiNM
pruning/recovery dynamics are visible.

Sharding: `batch(step)` is deterministic in (seed, step, host), so every
host can independently materialise its slice of the global batch — the
standard multi-host input pattern (no inter-host data traffic). With a mesh,
`sharded_batch` places each host's slice on the right devices via
`jax.make_array_from_process_local_data` semantics (single-process here:
device_put with the batch sharding).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_regimes: int = 4

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab, 4096)  # transition table cap
        self._v = v
        # sparse-ish row-stochastic transition tables, one per regime
        self._tables = []
        for _ in range(self.n_regimes):
            fan = 8
            nxt = rng.integers(0, v, size=(v, fan))
            logits = rng.normal(size=(v, fan)).astype(np.float32)
            p = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
            self._tables.append((nxt, p))

    def batch(self, step: int, start: int = 0, count: int | None = None) -> dict:
        """Host-local slice [start, start+count) of the global batch."""
        count = count or self.global_batch
        rng = np.random.default_rng((self.seed, step, start))
        toks = np.empty((count, self.seq_len + 1), dtype=np.int32)
        regime = rng.integers(0, self.n_regimes, size=count)
        cur = rng.integers(0, self._v, size=count)
        for t in range(self.seq_len + 1):
            toks[:, t] = cur
            u = rng.random(count)
            for r in range(self.n_regimes):
                sel = regime == r
                if not sel.any():
                    continue
                nxt, p = self._tables[r]
                rows = cur[sel]
                # vectorised categorical draw via inverse-CDF
                k = (u[sel][:, None] > np.cumsum(p[rows], axis=-1)).sum(-1)
                cur[sel] = nxt[rows, np.minimum(k, nxt.shape[1] - 1)]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterator(self, start_step: int = 0):
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


def make_batch_specs(seq_len: int, global_batch: int, vocab: int, frontend: str = "",
                     d_model: int = 0, frontend_tokens: int = 0):
    """ShapeDtypeStructs for one training batch (dry-run input specs)."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    if frontend == "patch":
        # frontend tokens + text tokens = seq_len; labels cover the full
        # sequence (image positions included — synthetic targets)
        specs["embeds"] = jax.ShapeDtypeStruct(
            (global_batch, frontend_tokens, d_model), jnp.bfloat16
        )
        specs["tokens"] = jax.ShapeDtypeStruct(
            (global_batch, seq_len - frontend_tokens), jnp.int32
        )
    elif frontend == "frames":
        specs["embeds"] = jax.ShapeDtypeStruct(
            (global_batch, seq_len, d_model), jnp.bfloat16
        )
        # enc-dec: decoder tokens are seq_len // 4 (DESIGN.md §6)
        specs["tokens"] = jax.ShapeDtypeStruct((global_batch, seq_len // 4), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((global_batch, seq_len // 4), jnp.int32)
    return specs
