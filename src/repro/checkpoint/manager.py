"""Sharding-independent checkpointing with async writes.

Layout: one directory per step, `leaf-<i>.npy` per pytree leaf plus a
`manifest.json` (treedef repr, leaf paths, shapes, dtypes, step). Leaves are
saved as *global logical arrays* — restore never depends on the mesh shape
that produced the checkpoint, so a run can resume on a different device
count (elastic restart): the restored arrays are simply re-placed with the
new run's shardings (`jax.device_put` with the target NamedSharding).

On a real multi-host pod each host writes only the shards it owns and the
manifest records per-shard index windows; the single-controller CPU
environment here degenerates to whole-leaf writes, but the API (save ->
wait -> restore(target_shardings)) is the production one.

Async: `save()` snapshots to host memory synchronously (cheap) and writes
to disk on a background thread, overlapping I/O with the next train steps —
`wait()` joins before the next save or on exit. Retention keeps the newest
`keep` checkpoints, and a `latest` symlink enables crash-restart discovery.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import ml_dtypes
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype name, including ml_dtypes ones (bfloat16, fp8)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(path: str, tree, step: int) -> None:
    names, leaves, _ = _flatten_with_names(tree)
    os.makedirs(path, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(leaf)
        fn = f"leaf-{i}.npy"
        np.save(os.path.join(path, fn), arr)
        manifest["leaves"].append(
            {"name": name, "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def restore(path: str, target_tree, shardings=None):
    """Restore into the structure of `target_tree` (matching by leaf order).

    `shardings`: optional pytree of NamedSharding to re-place leaves for the
    *current* mesh (elastic restart path)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten(target_tree)
    if len(flat) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, target has {len(flat)}"
        )
    out = []
    shard_flat = jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    for i, (leaf, meta) in enumerate(zip(flat, manifest["leaves"])):
        arr = np.load(os.path.join(path, meta["file"]))
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16 etc.) round-trip
            arr = arr.view(_np_dtype(meta["dtype"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"leaf {meta['name']}: shape {arr.shape} != {leaf.shape}")
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(root, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step-{step:08d}")

    def save_async(self, tree, step: int) -> None:
        self.wait()
        # snapshot to host memory synchronously; write on a worker thread
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            tmp = self._step_dir(step) + ".tmp"
            save(tmp, host_tree, step)
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            latest = os.path.join(self.root, "latest")
            if os.path.lexists(latest):
                os.remove(latest)
            os.symlink(os.path.basename(final), latest)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.root)
            if d.startswith("step-") and not d.endswith(".tmp")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_dir(self) -> str | None:
        latest = os.path.join(self.root, "latest")
        if os.path.exists(latest):
            return os.path.realpath(latest)
        return None

    def restore_latest(self, target_tree, shardings=None):
        d = self.latest_dir()
        if d is None:
            return None, -1
        return restore(d, target_tree, shardings)
