"""Pallas TPU kernel: HiNM sparse matmul  y = x @ W_packed^T.

TPU adaptation of the paper's SpMM (DESIGN.md §2, §5). Per grid cell
(one output tile x one batch block):

  1. *Indexed gather* — the tile's `vec_idx` row (VMEM, int32) selects the
     K kept input channels out of the (n_in, Bblk) activation block resident
     in VMEM. This is the TPU analogue of the paper's global->shared indexed
     load: a permuted `vec_idx` (the ICP order) costs exactly the same as an
     identity one, so the runtime input-channel reorder is free.
  2. *In-VMEM N:M decompression* — packed values (V, Kn) are expanded
     against their 2-bit slot indices to a dense (V, K) tile with a
     one-hot-compare contraction on the VPU (the STC-metadata equivalent;
     TPU has no sparse MXU so the N:M level buys bandwidth, not FLOPs).
  3. *Dense MXU contraction* — (V, K) @ (K, Bblk) accumulated in f32.

Layouts: activations enter as xT (n_in, B) so the gather runs on the
sublane axis; outputs leave as (n_out, B) with rows in packed (OCP) order.

VMEM budget per cell (defaults V=32, Bblk=256, bf16; see `pick_bblk`):
  xT block    n_in*Bblk*2   (e.g. 5120*256*2 = 2.5 MiB)
  gather      K*Bblk*2      (jnp.take stays in the activation dtype)
  weights     V*Kn*3 + K*4  (vals + int8 slot indices + vec_idx row)
  decompress  V*Kn*M*2 one-hot transient + V*K*2 dense tile
  accum       V*Bblk*4      (f32)
comfortably inside 16 MiB VMEM with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BBLK = 256
VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # conservative half of v5e VMEM


def _kernel(x_ref, vals_ref, nm_ref, idx_ref, out_ref, *, nn: int, mm: int):
    idx = idx_ref[0]                                  # (K,) int32
    xg = jnp.take(x_ref[...], idx, axis=0)            # (K, Bblk) sublane gather
    vals = vals_ref[0]                                # (V, Kn)
    slots = nm_ref[0].astype(jnp.int32)               # (V, Kn)
    v, kn = vals.shape
    g = kn // nn
    v4 = vals.reshape(v, g, nn)
    s4 = slots.reshape(v, g, nn)
    iota = jax.lax.broadcasted_iota(jnp.int32, (v, g, nn, mm), 3)
    w = (v4[..., None] * (iota == s4[..., None]).astype(vals.dtype)).sum(axis=2)
    w = w.reshape(v, g * mm)                          # (V, K) dense tile
    # inputs stay in the storage dtype (bf16 feeds the MXU natively; an
    # explicit f32 upcast would double the gather + tile VMEM footprint
    # that pick_bblk budgets); accumulation is f32 via preferred_element_type
    ct = jnp.promote_types(w.dtype, xg.dtype)
    acc = jax.lax.dot_general(
        w.astype(ct),
        xg.astype(ct),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = acc.astype(out_ref.dtype)


def pick_bblk(n_in: int, k: int, b: int, itemsize: int = 2, *, v: int = 32,
              nn: int = 2, mm: int = 4) -> int:
    """Largest batch block keeping the VMEM working set under budget.

    Working set per grid cell, with real itemsizes (the gather copy from
    ``jnp.take`` stays in the activation dtype — it is NOT a 4-byte f32
    copy — and the in-VMEM N:M decompress materialises a one-hot
    ``(V, G, N, M)`` transient plus the dense ``(V, K)`` tile):

      xT block      n_in * bblk * itemsize
      gather copy   k * bblk * itemsize
      weights       v*kn*(itemsize + 1) + k*4   (vals + int8 slots + vec_idx)
      decompress    v*kn*mm*itemsize + v*k*itemsize
      f32 accum     v * bblk * 4

    Only the first two and the accumulator scale with bblk; the weight and
    decompress terms are a fixed per-cell cost subtracted from the budget.
    The halving search itself is the shared ``ops.pick_tile`` (batch blocks
    need no divisibility — the wrapper pads the remainder).
    """
    from repro.kernels import ops

    kn = k // mm * nn
    fixed = (v * kn * (itemsize + 1) + k * 4
             + v * kn * mm * itemsize + v * k * itemsize)
    per_col = (n_in + k) * itemsize + v * 4
    bblk = ops.pick_tile(DEFAULT_BBLK, fixed, per_col,
                         budget=VMEM_BUDGET_BYTES, floor=8, divide=False)
    return max(8, min(bblk, max(8, b)))


@functools.partial(
    jax.jit, static_argnames=("nn", "mm", "bblk", "interpret", "out_dtype")
)
def hinm_spmm(
    x_t: jax.Array,       # (n_in, B) activations, transposed
    vals: jax.Array,      # (T, V, Kn)
    nm_idx: jax.Array,    # (T, V, Kn) int8
    vec_idx: jax.Array,   # (T, K) int32
    *,
    nn: int = 2,
    mm: int = 4,
    bblk: int | None = None,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Returns y_t (n_out, B) = W_packed @ x, rows in packed order."""
    n_in, b = x_t.shape
    t, v, kn = vals.shape
    k = vec_idx.shape[-1]
    if kn != k // mm * nn:
        raise ValueError(f"Kn={kn} inconsistent with K={k}, {nn}:{mm}")
    out_dtype = out_dtype or x_t.dtype
    bblk = bblk or pick_bblk(n_in, k, b, jnp.dtype(x_t.dtype).itemsize,
                             v=v, nn=nn, mm=mm)
    if b % bblk != 0:
        pad = bblk - b % bblk
        x_t = jnp.pad(x_t, ((0, 0), (0, pad)))
    bp = x_t.shape[1]

    out = pl.pallas_call(
        functools.partial(_kernel, nn=nn, mm=mm),
        grid=(t, bp // bblk),
        in_specs=[
            pl.BlockSpec((n_in, bblk), lambda i, j: (0, j)),
            pl.BlockSpec((1, v, kn), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, v, kn), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((v, bblk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t * v, bp), out_dtype),
        interpret=interpret,
    )(x_t, vals, nm_idx, vec_idx)
    return out[:, :b]
