"""Pure-jnp oracles for every Pallas kernel in this package.

Also hosts the XLA "fast path" formulations used on CPU and inside the
dry-run serve_step (their HLO carries the same gather + decompress +
matmul structure the TPU kernel realises, so roofline terms derived from
them are representative).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.types import HiNMConfig, PackedHiNM


def decompress_tiles(
    vals: jax.Array, nm_idx: jax.Array, m: int, n: int
) -> jax.Array:
    """(T, V, Kn) packed values + slots -> (T, V, K) dense kept-column tiles."""
    t, v, kn = vals.shape
    g = kn // n
    v4 = vals.reshape(t, v, g, n)
    s4 = nm_idx.reshape(t, v, g, n).astype(jnp.int32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (t, v, g, n, m), 4)
    dense = (v4[..., None] * (iota == s4[..., None]).astype(vals.dtype)).sum(axis=3)
    return dense.reshape(t, v, g * m)


def hinm_spmm_oracle(x: jax.Array, p: PackedHiNM) -> jax.Array:
    """Ground truth: unpack to masked-dense and matmul. x: (B, n_in)."""
    w = packing.unpack(p)  # (n_out, n_in), rows in packed (OCP) order
    return (x.astype(jnp.float32) @ w.astype(jnp.float32).T).astype(x.dtype)


GATHER_PATH_MAX_ROWS = 1024
TILE_CHUNK_BYTES = 256 * 1024 * 1024


def _gather_matmul(x, vec_idx, vals, nm_idx, mm, nn, out_dtype):
    """(B, n_in) x packed tiles -> (B, T, V): gather + compressed contraction.
    Operands stay in storage dtype; the MXU accumulates in f32."""
    xg = jnp.take(x, vec_idx, axis=1)                      # (B, T', K)
    w = decompress_tiles(vals, nm_idx, mm, nn)             # (T', V, K)
    return jnp.einsum(
        "btk,tvk->btv", xg, w.astype(xg.dtype),
        preferred_element_type=jnp.float32,
    ).astype(out_dtype)


def hinm_spmm_xla(x: jax.Array, p: PackedHiNM, chunk_bytes: int | None = None) -> jax.Array:
    """XLA fast path: per-tile gather + in-register decompress + matmul.

    Mirrors the TPU kernel's dataflow: the `vec_idx` gather plays the
    global->shared indexed load role (free runtime reorder), decompression
    expands the N:M values against their slot indices, and the contraction
    runs over the K kept columns only (the vector-sparsity FLOP saving).

    For large row counts (prefill / train eval) the whole-matrix gather
    would materialise a (B, T, K) activation copy, so tiles are processed
    in chunks with lax.map — bounded memory, same compressed FLOPs. The
    Pallas TPU kernel streams the same dataflow through VMEM.
    """
    cfg = p.config
    b = x.shape[0]
    t, v, kn = p.vals.shape
    k = p.vec_idx.shape[-1]
    if b <= GATHER_PATH_MAX_ROWS:
        y = _gather_matmul(x, p.vec_idx, p.vals, p.nm_idx, cfg.m, cfg.n, x.dtype)
        return y.reshape(b, p.n_out)

    # chunk tiles so the transient (B, tc, K) stays under budget; shapes
    # here are GLOBAL (SPMD), so scale the budget by the device count
    from repro.models import probe_mode

    budget = (chunk_bytes or TILE_CHUNK_BYTES) * max(1, jax.device_count())
    tc = max(1, budget // max(1, b * k * x.dtype.itemsize))
    tc = min(t, tc)
    if probe_mode.enabled():
        tc = t  # single chunk: all FLOPs visible to cost_analysis
    while t % tc:
        tc -= 1
    nchunk = t // tc

    def one(args):
        vi, va, nm = args
        return _gather_matmul(x, vi, va, nm, cfg.m, cfg.n, x.dtype)

    ys = jax.lax.map(one, (
        p.vec_idx.reshape(nchunk, tc, k),
        p.vals.reshape(nchunk, tc, v, kn),
        p.nm_idx.reshape(nchunk, tc, v, kn),
    ))                                                     # (nchunk, B, tc, V)
    return jnp.moveaxis(ys, 0, 1).reshape(b, p.n_out)


def hinm_spmm_shard_map(x: jax.Array, p: PackedHiNM) -> jax.Array | None:
    """Beyond-paper §Perf: explicit shard_map realisation of the packed
    matmul. Tiles are independent (DESIGN.md §2), so with vec_idx/vals
    T-sharded over 'model' and activations batch-sharded over dp, every
    shard's gather+contraction is fully local — ZERO collectives, where
    XLA SPMD's gather partitioner instead all-gathers the full activations
    (the dominant collective in every baseline prefill cell).

    Returns None when preconditions don't hold (no mesh context, tile or
    batch dims don't divide) — caller falls back to the XLA path.
    """
    from repro import compat

    am = compat.get_abstract_mesh()
    if am is None or am.empty or "model" in getattr(am, "manual_axes", ()):
        return None
    if "model" not in am.axis_names:
        return None
    t, v, kn = p.vals.shape[-3:]
    if p.vals.ndim != 3:  # expert stacks keep the vmapped path
        return None
    nmodel = am.shape["model"]
    if t % nmodel:
        return None
    b = x.shape[0]
    dp = tuple(a for a in ("pod", "data") if a in am.axis_names)
    ndp = 1
    for a in dp:
        ndp *= am.shape[a]
    row_spec = dp if (dp and b % ndp == 0) else None
    P = jax.sharding.PartitionSpec
    cfg = p.config

    def body(xl, vl, nl, il):
        return _gather_matmul(xl, il, vl, nl, cfg.m, cfg.n, x.dtype)

    y = compat.shard_map(
        body,
        mesh=am,
        in_specs=(P(row_spec, None), P("model", None, None),
                  P("model", None, None), P("model", None)),
        out_specs=P(row_spec, "model", None),
    )(x, p.vals, p.nm_idx, p.vec_idx)
    return y.reshape(b, p.n_out)


def scatter_dense(p: PackedHiNM) -> jax.Array:
    """Decompress packed -> masked-dense (n_out, n_in); memory = one dense
    weight (scatter by kept-column ids, stays tile-sharded under SPMD)."""
    return packing.unpack(p)


def nm_select_ref(w: jax.Array, n: int = 2, m: int = 4) -> jax.Array:
    """Oracle for the fused train-time N:M select: keep top-N of each M group
    along the last axis (by |w|), zero the rest."""
    shape = w.shape
    g = w.reshape(shape[:-1] + (shape[-1] // m, m))
    mag = jnp.abs(g)
    order = jnp.argsort(mag, axis=-1, descending=True)
    ranks = jnp.argsort(order, axis=-1)
    return jnp.where((ranks < n), g, 0).reshape(shape)


def gather_cols_ref(x: jax.Array, idx: jax.Array) -> jax.Array:
    """Oracle for the runtime input-channel reorder gather. x:(B,n), idx:(T,K)."""
    return jnp.take(x, idx, axis=1)
