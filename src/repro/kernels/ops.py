"""Public jit'd wrappers around the Pallas kernels, with CPU routing.

`backend="auto"` uses the Pallas kernel on TPU and the XLA fast-path
formulation elsewhere (same dataflow, so CPU tests and dry-run HLO remain
representative). `backend="interpret"` forces the Pallas kernel in
interpret mode — the correctness-validation path exercised by tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import PackedHiNM
from repro.kernels import hinm_spmm as _spmm
from repro.kernels import nm_select as _nmsel
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def hinm_matmul(
    x: jax.Array,
    p: PackedHiNM,
    backend: str = "auto",
    chunk_bytes: int | None = None,
) -> jax.Array:
    """y (..., n_out) = x (..., n_in) @ W_packed^T (rows in packed order)."""
    lead = x.shape[:-1]
    xb = x.reshape(-1, x.shape[-1])
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "xla":
        from repro.perf_knobs import KNOBS

        y = None
        if KNOBS.packed_shard_map:
            y = _ref.hinm_spmm_shard_map(xb, p)
        if y is None:
            y = _ref.hinm_spmm_xla(xb, p, chunk_bytes=chunk_bytes)
    elif backend in ("pallas", "interpret"):
        y_t = _spmm.hinm_spmm(
            xb.T,
            p.vals,
            p.nm_idx,
            p.vec_idx,
            nn=p.config.n,
            mm=p.config.m,
            interpret=(backend == "interpret") or not _on_tpu(),
            out_dtype=x.dtype,
        )
        y = y_t.T
    elif backend == "oracle":
        y = _ref.hinm_spmm_oracle(xb, p)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return y.reshape(*lead, p.n_out)


def nm_apply(w: jax.Array, nn: int = 2, mm: int = 4, backend: str = "auto") -> jax.Array:
    """Apply N:M magnitude selection along the last axis (any leading dims)."""
    lead = w.shape[:-1]
    wb = w.reshape(-1, w.shape[-1])
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "xla":
        out = _ref.nm_select_ref(wb, nn, mm)
    elif backend in ("pallas", "interpret"):
        out = _nmsel.nm_select(
            wb, nn=nn, mm=mm, interpret=(backend == "interpret") or not _on_tpu()
        )
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return out.reshape(*lead, w.shape[-1])
