"""Public jit'd wrappers around the Pallas kernels, with CPU routing.

`backend="auto"` uses the Pallas kernel on TPU and the XLA fast-path
formulation elsewhere (same dataflow, so CPU tests and dry-run HLO remain
representative). `backend="interpret"` forces the Pallas kernel in
interpret mode — the correctness-validation path exercised by tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import PackedHiNM
from repro.kernels import hinm_spmm as _spmm
from repro.kernels import nm_select as _nmsel
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# conservative half of v5e VMEM — shared by every kernel's tile picker
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def pick_tile(n_units: int, fixed_bytes: int, per_unit_bytes: int, *,
              budget: int = VMEM_BUDGET_BYTES, start: int | None = None,
              floor: int = 1, divide: bool = True) -> int:
    """Largest tile with ``fixed + per_unit * tile`` under the VMEM budget.

    The shared discipline behind ``hinm_spmm.pick_bblk`` and
    ``paged_attn.pick_pp``: start from ``min(start, n_units)`` and halve
    until the working set fits (and, when ``divide``, the tile divides
    ``n_units`` so the grid needs no remainder handling). Never returns
    less than ``floor`` — a single minimal tile must fit by construction.
    """
    t = max(floor, int(n_units) if start is None else min(int(start), int(n_units)))
    while t > floor and (fixed_bytes + per_unit_bytes * t > budget
                        or (divide and n_units % t)):
        t = max(floor, t // 2)
    return t


def _count_dispatch(decision: str, reason: str) -> None:
    """Tally a paged-attention backend decision in the global metrics
    registry. Imported lazily: `repro.serve.telemetry` must not be a
    module-level dependency of the kernel layer (the serve package sits
    above it in the import graph)."""
    from repro.serve.telemetry import metrics as _tm

    _tm.GLOBAL.counter("paged_attn_dispatch",
                       labels={"decision": decision, "reason": reason}).inc()


def paged_attention(
    q: jax.Array,          # (B, s, H, hd)
    k_pool: jax.Array,     # (n_pages, page, KV, hd)
    v_pool: jax.Array,     # (n_pages, page, KV, hd)
    kpos_pool: jax.Array,  # (n_pages, page) int32
    bt: jax.Array,         # (B, n_bt) int32
    q_pos: jax.Array,      # (B, s) int32
    *,
    window: int = 0,
    backend: str = "auto",
) -> jax.Array | None:
    """Block-table-resolved decode attention over a paged KV pool.

    Returns (B, s, H, hd), or None when the chosen backend defers to the
    caller's jnp ``pool[bt]`` gather path ("off", or "auto" off-TPU —
    interpret mode is a correctness harness, not a CPU fast path).

    Every call lands a labeled count in the process-global telemetry
    registry (decision + deferral reason).  This function runs at trace
    time — once per XLA trace, not per decode step — so the counters
    report *dispatch decisions*, exactly the attribution the serving
    observability layer wants, at zero steady-state cost.
    """
    if backend in ("off", "gather"):
        _count_dispatch("gather", "knob-off")
        return None
    if backend == "auto":
        if not _on_tpu():
            _count_dispatch("gather", "auto-no-tpu")
            return None
        backend = "pallas"
        _count_dispatch("pallas", "auto-tpu")
    elif backend in ("pallas", "on", "interpret"):
        _count_dispatch("interpret" if backend == "interpret" else "pallas",
                        "forced")
    if backend not in ("pallas", "on", "interpret"):
        raise ValueError(f"unknown paged-attention backend {backend!r}")
    from repro.kernels import paged_attn as _pattn

    return _pattn.paged_decode_attn(
        q, k_pool, v_pool, kpos_pool, bt, q_pos, window=window,
        interpret=(backend == "interpret") or not _on_tpu())


def hinm_matmul(
    x: jax.Array,
    p: PackedHiNM,
    backend: str = "auto",
    chunk_bytes: int | None = None,
) -> jax.Array:
    """y (..., n_out) = x (..., n_in) @ W_packed^T (rows in packed order)."""
    lead = x.shape[:-1]
    xb = x.reshape(-1, x.shape[-1])
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "xla":
        from repro.perf_knobs import KNOBS

        y = None
        if KNOBS.packed_shard_map:
            y = _ref.hinm_spmm_shard_map(xb, p)
        if y is None:
            y = _ref.hinm_spmm_xla(xb, p, chunk_bytes=chunk_bytes)
    elif backend in ("pallas", "interpret"):
        y_t = _spmm.hinm_spmm(
            xb.T,
            p.vals,
            p.nm_idx,
            p.vec_idx,
            nn=p.config.n,
            mm=p.config.m,
            interpret=(backend == "interpret") or not _on_tpu(),
            out_dtype=x.dtype,
        )
        y = y_t.T
    elif backend == "oracle":
        y = _ref.hinm_spmm_oracle(xb, p)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return y.reshape(*lead, p.n_out)


def nm_apply(w: jax.Array, nn: int = 2, mm: int = 4, backend: str = "auto") -> jax.Array:
    """Apply N:M magnitude selection along the last axis (any leading dims)."""
    lead = w.shape[:-1]
    wb = w.reshape(-1, w.shape[-1])
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "xla":
        out = _ref.nm_select_ref(wb, nn, mm)
    elif backend in ("pallas", "interpret"):
        out = _nmsel.nm_select(
            wb, nn=nn, mm=mm, interpret=(backend == "interpret") or not _on_tpu()
        )
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return out.reshape(*lead, w.shape[-1])
