"""Pallas TPU kernel: paged-attention decode over the serve slot pool.

The serving hot path resolves a slot's KV rows through its block table
``bt`` — the runtime analogue of the HiNM kernel's ``vec_idx``
(models/paging.py).  The jnp reference path materialises the full logical
view first (``pool[bt]`` gather: O(n_bt * page) rows copied per step, per
layer) and then runs chunked online-softmax attention over the copy.  This
kernel fuses the two: the grid walks the block table directly, one program
per (slot, kv-head) streaming that slot's pages HBM->VMEM via
scalar-prefetched index maps, with flash-style online-softmax accumulation
in VMEM scratch — the contiguous view is never built.

Grid ``(B, KV, n_bt // pp)``: the last (innermost) dimension streams the
slot's block-table entries, ``pp`` pages per step.  ``pp`` is picked with
the same VMEM-budget discipline as ``hinm_spmm.pick_bblk`` (see
``ops.pick_tile``): the per-page working set (k/v blocks + f32 upcasts +
score tile) is halved against the budget, so arbitrarily large pages or
head dims degrade to fewer pages per step instead of spilling VMEM.  Each
page is fetched by an index map that reads ``bt[b, i*pp + j]`` from SMEM
(``PrefetchScalarGridSpec``) — a permuted block table costs exactly the
same as an identity one, the paper's indexed-gather trick applied to the
KV cache.

Masking folds every paged-pool invariant into one comparison chain:

  * sentinel pages (unallocated block-table tail) hold ``kpos = 2**30``,
    so ``kpos <= q_pos`` masks them with no extra branch;
  * rollback-swept rows (rejected speculative writes) had their ``kpos``
    reset to the sentinel and mask identically;
  * a sliding window adds ``kpos > q_pos - window`` (hybrid rings).

Queries enter pre-scaled f32 as ``(B, KV, s*G, hd)`` — s decode rows per
slot (s=1 decode, s=k+1 speculative verify; the causal mask hides each
row's future rows exactly as the gather path does).  K/V stay in the pool
storage dtype until the per-page VMEM upcast, matching the reference
``_attn_qchunk`` dataflow, and the epilogue divides by
``max(l, 1e-30)`` like the reference so fully-masked rows agree bitwise.

``interpret=True`` runs the same kernel through the Pallas interpreter so
CPU CI validates it against the gather path (kernels/ops routes this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, q_ref, qpos_ref, *refs, pp: int, window: int):
    k_refs = refs[:pp]
    v_refs = refs[pp:2 * pp]
    p_refs = refs[2 * pp:3 * pp]
    o_ref = refs[3 * pp]
    m_ref, l_ref, acc_ref = refs[3 * pp + 1:]
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                   # (Gs, hd) f32 pre-scaled
    qpos = qpos_ref[0]                                # (Gs,) int32
    for j in range(pp):
        kj = k_refs[j][0, :, 0, :].astype(jnp.float32)    # (page, hd)
        vj = v_refs[j][0, :, 0, :].astype(jnp.float32)
        kp = p_refs[j][0]                                 # (page,) int32
        s = jax.lax.dot_general(
            q, kj, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (Gs, page)
        msk = kp[None, :] <= qpos[:, None]
        if window:
            msk &= kp[None, :] > qpos[:, None] - window
        s = jnp.where(msk, s, NEG_INF)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_ref[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, vj, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(i == pl.num_programs(2) - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


def pick_pp(n_bt: int, page: int, hd: int, gs: int, itemsize: int) -> int:
    """Pages streamed per grid step, VMEM-budgeted like ``pick_bblk``.

    Per-page working set: the k/v blocks in storage dtype, their f32
    upcasts, the kpos row, and the (Gs, page) score/probability transients.
    Fixed per-program cost: the pre-scaled q tile, the f32 accumulator and
    output tile, and the (Gs, 128) m/l statistic scratch.
    """
    from repro.kernels import ops

    fixed = gs * hd * 4 * 3 + gs * 128 * 4 * 2 + gs * 4
    per_page = (page * hd * (itemsize + 4) * 2   # k/v blocks + f32 upcasts
                + page * 4                       # kpos row
                + gs * page * 4 * 3)             # scores / probs / mask
    return ops.pick_tile(n_bt, fixed, per_page, start=8)


@functools.partial(jax.jit, static_argnames=("window", "pp", "interpret"))
def paged_decode_attn(
    q: jax.Array,          # (B, s, H, hd) — s decode rows per slot
    k_pool: jax.Array,     # (n_pages, page, KV, hd)
    v_pool: jax.Array,     # (n_pages, page, KV, hd)
    kpos_pool: jax.Array,  # (n_pages, page) int32
    bt: jax.Array,         # (B, n_bt) int32 block table
    q_pos: jax.Array,      # (B, s) int32 absolute query positions
    *,
    window: int = 0,
    pp: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Block-table-resolved decode attention. Returns (B, s, H, hd)."""
    b, s, h, hd = q.shape
    n_pages, page, kvh, _ = k_pool.shape
    g = h // kvh
    gs = s * g
    n_bt = bt.shape[1]
    pp = pp or pick_pp(n_bt, page, hd, gs, jnp.dtype(k_pool.dtype).itemsize)

    scale = hd ** -0.5
    # row layout (s, G) flattened s-major: row r belongs to query s-index
    # r // G, so its position is q_pos repeated G times along the row axis
    qf = (q.astype(jnp.float32) * scale).reshape(b, s, kvh, g, hd)
    qf = jnp.moveaxis(qf, 2, 1).reshape(b, kvh, gs, hd)
    qpos = jnp.repeat(q_pos.astype(jnp.int32), g, axis=1)     # (B, Gs)

    def pool_map(j):
        return lambda bi, hi, ii, tbl: (tbl[bi, ii * pp + j], 0, hi, 0)

    def kpos_map(j):
        return lambda bi, hi, ii, tbl: (tbl[bi, ii * pp + j], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, n_bt // pp),
        in_specs=(
            [pl.BlockSpec((1, 1, gs, hd), lambda bi, hi, ii, tbl: (bi, hi, 0, 0)),
             pl.BlockSpec((1, gs), lambda bi, hi, ii, tbl: (bi, 0))]
            + [pl.BlockSpec((1, page, 1, hd), pool_map(j)) for j in range(pp)]
            + [pl.BlockSpec((1, page, 1, hd), pool_map(j)) for j in range(pp)]
            + [pl.BlockSpec((1, page), kpos_map(j)) for j in range(pp)]
        ),
        out_specs=pl.BlockSpec((1, 1, gs, hd),
                               lambda bi, hi, ii, tbl: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gs, 128), jnp.float32),   # running max m
            pltpu.VMEM((gs, 128), jnp.float32),   # running sum l
            pltpu.VMEM((gs, hd), jnp.float32),    # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, pp=pp, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, gs, hd), jnp.float32),
        interpret=interpret,
    )(bt.astype(jnp.int32), qf, qpos,
      *([k_pool] * pp), *([v_pool] * pp), *([kpos_pool] * pp))

    out = jnp.moveaxis(out.reshape(b, kvh, s, g, hd), 1, 2)
    return out.reshape(b, s, h, hd).astype(q.dtype)
