"""Pallas TPU kernel: fused N:M magnitude select (training-time pruning).

Keeps the top-N-of-each-M group along the last axis by |w| and zeroes the
rest, in one VMEM pass. Used by the gradual-pruning train step, where the
mask is recomputed from the live weights every pruning interval.

Rank computation is an O(M^2) compare-reduce (M is 4): rank_i = #{j :
|w_j| > |w_i|  or  (|w_j| == |w_i| and j < i)} — sort-free, VPU-friendly,
and bit-exact against the argsort-based oracle in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, out_ref, *, nn: int, mm: int):
    w = w_ref[...]                                     # (Rblk, Cblk)
    r, c = w.shape
    g = w.reshape(r, c // mm, mm)
    mag = jnp.abs(g)
    a = mag[..., :, None]                              # (R, G, M, 1) self
    b = mag[..., None, :]                              # (R, G, 1, M) other
    ii = jax.lax.broadcasted_iota(jnp.int32, (r, c // mm, mm, mm), 2)
    jj = jax.lax.broadcasted_iota(jnp.int32, (r, c // mm, mm, mm), 3)
    beats = (b > a) | ((b == a) & (jj < ii))
    rank = beats.sum(axis=3)                           # (R, G, M)
    keep = rank < nn
    out_ref[...] = jnp.where(keep, g, 0).reshape(r, c)


@functools.partial(
    jax.jit, static_argnames=("nn", "mm", "rblk", "cblk", "interpret")
)
def nm_select(
    w: jax.Array,
    *,
    nn: int = 2,
    mm: int = 4,
    rblk: int = 256,
    cblk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Top-N-of-M select along the last axis of a 2-D array."""
    rows, cols = w.shape
    if cols % mm != 0:
        raise ValueError(f"cols={cols} % M={mm} != 0")
    rblk = min(rblk, rows)
    cblk = min(cblk, cols)
    # block must hold whole M-groups
    cblk = max(mm, (cblk // mm) * mm)
    if rows % rblk != 0 or cols % cblk != 0:
        # fall back to one row/col block if shapes don't tile evenly
        rblk = rows if rows % rblk else rblk
        cblk = cols if cols % cblk else cblk
    return pl.pallas_call(
        functools.partial(_kernel, nn=nn, mm=mm),
        grid=(rows // rblk, cols // cblk),
        in_specs=[pl.BlockSpec((rblk, cblk), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((rblk, cblk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), w.dtype),
        interpret=interpret,
    )(w)
