"""Phase 2 — fold searched permutations along graph edges.

All helpers operate on STORED orientation (n_in, n_out) weights — HiNM rows
are stored columns. `perm` may carry a leading expert axis (E, n_out) for
MoE expert stacks; weight leaves then carry a matching (E, n_in, n_out).

Folding rules by edge kind:
  self / tied         : permute the stored n_out axis (+ bias)
  producer → consumer : permute the consumer's stored n_in axis
  gqa-expand          : expand the within-kv-head perm to query heads
                        first, then permute the consumer's n_in axis
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def gqa_expand_perm(perm_v: np.ndarray, n_kv: int, n_heads: int, hd: int) -> np.ndarray:
    """Expand a (KV*hd) within-kv-head row perm to the (H*hd) wo-column perm."""
    g = n_heads // n_kv
    out = np.empty(n_heads * hd, dtype=np.int64)
    for h in range(n_heads):
        kv = h // g
        local = perm_v[kv * hd : (kv + 1) * hd] - kv * hd
        out[h * hd : (h + 1) * hd] = h * hd + local
    return out


def permute_out(w, perm):
    """Permute the stored n_out axis (axis -1) — producer row perm."""
    if w.ndim == 3:
        return jnp.stack([jnp.take(w[e], jnp.asarray(perm[e]), axis=1)
                          for e in range(w.shape[0])])
    return jnp.take(w, jnp.asarray(perm), axis=1)


def permute_bias(b, perm):
    if b.ndim == 2:
        return jnp.stack([jnp.take(b[e], jnp.asarray(perm[e]))
                          for e in range(b.shape[0])])
    return jnp.take(b, jnp.asarray(perm))


def permute_in(w, perm):
    """Permute the stored n_in axis — consumer column perm."""
    if w.ndim == 3:
        p = perm if perm.ndim == 2 else np.broadcast_to(perm, (w.shape[0],) + perm.shape)
        return jnp.stack([jnp.take(w[e], jnp.asarray(p[e]), axis=0)
                          for e in range(w.shape[0])])
    return jnp.take(w, jnp.asarray(perm), axis=0)


def is_identity(perm) -> bool:
    if perm.ndim == 2:
        return all(np.array_equal(p, np.arange(p.shape[0])) for p in perm)
    return np.array_equal(perm, np.arange(perm.shape[0]))


# ---------------------------------------------------------------------------
# consistency validation — the invariants the walker only held implicitly
# ---------------------------------------------------------------------------


def check_bijection(perm: np.ndarray, what: str) -> None:
    flat = perm.reshape(-1, perm.shape[-1]) if perm.ndim == 2 else perm[None]
    for p in flat:
        if not np.array_equal(np.sort(p), np.arange(p.shape[0])):
            raise ValueError(f"{what}: folded perm is not a bijection")


def check_identity(perm: np.ndarray, what: str) -> None:
    if not is_identity(perm):
        raise ValueError(f"{what}: residual-identity constraint violated")


def check_block_diagonal(perm: np.ndarray, row_blocks: int, what: str) -> None:
    flat = perm.reshape(-1, perm.shape[-1]) if perm.ndim == 2 else perm[None]
    bs = flat.shape[-1] // row_blocks
    for p in flat:
        src_blocks = p // bs
        dst_blocks = np.arange(p.shape[0]) // bs
        if not np.array_equal(src_blocks, dst_blocks):
            raise ValueError(
                f"{what}: block-diagonal constraint violated "
                f"(a row crossed one of the {row_blocks} blocks)"
            )
