"""Phase 1 — permutation search for one projection (HiNM orientation).

One implementation shared by `train.pruning`, `core.api.prune_matrix`, and
the virtual (mask-only) path; previously these carried two diverging
copies. Methods:

  gyro      : annealed-sampling OCP + Hungarian ICP (the paper's algorithm)
  ocp_only / icp_only / noperm : ablations of the two phases
  v1        : OVW-style one-shot k-means OCP + our ICP   (baseline HiNM-V1)
  v2        : our OCP + Apex-style greedy swap ICP       (baseline HiNM-V2)

OCP runs on `sal_rows` (the search saliency, optionally extended with tied
partners' columns so the shared row perm is chosen jointly), per contiguous
row block when the node is block-diagonal constrained. ICP then runs on the
row-permuted `sal`.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import baselines, gyro, sparsity
from repro.core.types import HiNMConfig
from repro.perm.cache import PermCache, search_key


def search_projection(
    sal: np.ndarray,
    sal_rows: np.ndarray,
    hcfg: HiNMConfig,
    *,
    method: str = "gyro",
    can_permute_rows: bool = True,
    row_blocks: int = 1,
    rng: np.random.Generator | None = None,
    ocp_iters: int = 8,
    icp_iters: int = 8,
    cache: PermCache | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Search on (n_out, n_in) saliency. Returns (out_perm, col_order).

    `col_order` is (T, K): absolute kept-column ids per tile in ICP order —
    exactly the vec_idx the packed format stores.
    """
    rng = rng or np.random.default_rng(0)
    n_out = sal.shape[0]
    if method not in ("gyro", "noperm", "icp_only", "ocp_only", "v1", "v2"):
        raise ValueError(f"unknown method {method!r}")

    key = None
    if cache is not None:
        key = search_key(sal, sal_rows, hcfg, method=method,
                         can_permute_rows=can_permute_rows,
                         row_blocks=row_blocks, ocp_iters=ocp_iters,
                         icp_iters=icp_iters)
        hit = cache.get(key)
        if hit is not None:
            return hit

    run_ocp = can_permute_rows and method in ("gyro", "ocp_only", "v1", "v2")
    run_icp = method in ("gyro", "icp_only", "v1", "v2")

    if run_ocp:
        padded = np.pad(sal_rows, ((0, 0), (0, (-sal_rows.shape[1]) % hcfg.m)))
        bs = n_out // row_blocks
        perms = []
        for b in range(row_blocks):
            blk = padded[b * bs : (b + 1) * bs]
            if method == "v1":
                p = baselines.ovw_ocp(blk, hcfg, rng)
            else:
                p, _ = gyro.ocp(blk, hcfg, iters=ocp_iters, rng=rng)
            perms.append(p + b * bs)
        out_perm = np.concatenate(perms)
    else:
        out_perm = np.arange(n_out)

    sal_p = sal[out_perm]
    if run_icp and method == "v2":
        col_ids = np.asarray(sparsity.kept_column_ids(jnp.asarray(sal_p), hcfg))
        t = col_ids.shape[0]
        gathered = np.take_along_axis(
            sal_p.reshape(t, hcfg.v, -1), col_ids[:, None, :], axis=2
        )
        col_order = np.empty_like(col_ids)
        for ti in range(t):
            o = baselines.apex_icp_tile(gathered[ti], hcfg, rng)
            col_order[ti] = col_ids[ti][o]
    else:
        res = gyro.gyro_permute(sal_p, hcfg, icp_iters=icp_iters, rng=rng,
                                run_ocp=False, run_icp=run_icp)
        col_order = res.col_order

    col_order = np.asarray(col_order, dtype=np.int32)
    if cache is not None:
        cache.put(key, out_perm, col_order)
    return out_perm, col_order
