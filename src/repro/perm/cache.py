"""Saliency-hash permutation cache.

Keyed on the exact bytes of the saliency matrices plus everything else that
determines a search result (HiNM config, method, iteration budgets, row
freedom). Repeated gradual-pruning refreshes — and any other repeated
`prune_model` over unchanged weights — skip the gyro search entirely.

The RNG stream is deliberately NOT part of the key: two searches over
byte-identical saliency are the same problem, and any cached answer is a
valid answer for both.
"""
from __future__ import annotations

import collections
import hashlib
import threading

import numpy as np


def _hash_array(a: np.ndarray) -> str:
    a = np.ascontiguousarray(a)
    h = hashlib.sha1(str(a.shape).encode())
    h.update(str(a.dtype).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def search_key(sal, sal_rows, hcfg, *, method: str, can_permute_rows: bool,
               row_blocks: int, ocp_iters: int, icp_iters: int) -> tuple:
    sal_h = _hash_array(sal)
    rows_h = sal_h if sal_rows is sal else _hash_array(sal_rows)
    return (sal_h, rows_h, hcfg.v, hcfg.n, hcfg.m, hcfg.vector_sparsity,
            method, can_permute_rows, row_blocks, ocp_iters, icp_iters)


class PermCache:
    """Thread-safe LRU of (out_perm, col_order) search results."""

    def __init__(self, maxsize: int = 4096):
        self.maxsize = maxsize
        self._store: collections.OrderedDict[tuple, tuple] = collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: tuple):
        with self._lock:
            hit = self._store.get(key)
            if hit is None:
                self.misses += 1
                return None
            self._store.move_to_end(key)
            self.hits += 1
            out_perm, col_order = hit
        return out_perm.copy(), col_order.copy()

    def put(self, key: tuple, out_perm: np.ndarray, col_order: np.ndarray):
        with self._lock:
            self._store[key] = (np.asarray(out_perm).copy(),
                                np.asarray(col_order).copy())
            self._store.move_to_end(key)
            while len(self._store) > self.maxsize:
                self._store.popitem(last=False)

    def __len__(self) -> int:
        return len(self._store)

    def clear(self):
        with self._lock:
            self._store.clear()
            self.hits = self.misses = 0
