"""Phase 3 — pack + mask + report from search results.

One implementation shared by `prune_model`, `prune_matrix`, and the
virtual (mask-only) path. All functions here take HiNM orientation
(n_out, n_in); `realize_stored` adapts the stored (n_in, n_out) layout the
model trees use.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import packing, sparsity
from repro.core.types import HiNMConfig, PackedHiNM


@dataclasses.dataclass
class Realized:
    """Packed/masked projection. Arrays are HiNM orientation (n_out, n_in);
    `w_p` and `mask_p` are aligned to the PERMUTED row order."""

    w_p: jnp.ndarray
    mask_p: jnp.ndarray
    packed: PackedHiNM
    retained: float       # fraction of magnitude saliency kept


def realize_matrix(w, out_perm, col_order, hcfg: HiNMConfig,
                   pack: bool = True, sal=None) -> Realized:
    """Pack one (n_out, n_in) weight given search results.

    Packing and the mask both select N:M survivors from the same saliency
    (`sal` in ORIGINAL row order, defaulting to the permuted weight's
    magnitude), so their supports are identical.
    """
    w_p = jnp.take(jnp.asarray(w), jnp.asarray(out_perm), axis=0)
    if sal is None:
        sal_p = jnp.abs(w_p.astype(jnp.float32))
    else:
        sal_p = jnp.take(jnp.asarray(sal, dtype=jnp.float32),
                         jnp.asarray(out_perm), axis=0)
    col = jnp.asarray(col_order)
    packed = packing.pack(w_p, hcfg, col_ids=col, sal=sal_p) if pack else None
    mask_p = sparsity.hinm_mask_from_columns(sal_p, col, hcfg)
    retained = float(jnp.sum(sal_p * mask_p) / jnp.maximum(sal_p.sum(), 1e-30))
    return Realized(w_p=w_p, mask_p=mask_p, packed=packed, retained=retained)


def realize_stored(w_stored, out_perm, col_order, hcfg: HiNMConfig,
                   pack: bool = True):
    """Stored-orientation wrapper: (n_in, n_out) in, stored-orientation out.

    Returns (w_permuted, mask, packed, retained) with w/mask transposed
    back to storage layout.
    """
    r = realize_matrix(jnp.asarray(w_stored).T, out_perm, col_order, hcfg,
                       pack=pack)
    return r.w_p.T, r.mask_p.T, r.packed, r.retained


def mask_to_original_rows(mask_p, out_perm, axis: int = 0):
    """Map a permuted-row mask back to the original row order (virtual
    pruning: params untouched, tiles become non-contiguous row sets)."""
    inv = np.argsort(out_perm)
    return jnp.take(mask_p, jnp.asarray(inv), axis=axis)
