"""Search/propagate/realize orchestration over a ModelPermGraph.

Work items are (container, layer index, node): every stacked layer of every
container contributes one search per node (MoE expert stacks loop experts
inside one item). Items are independent unless a coupling edge links their
nodes within the same layer, so the engine runs a wavefront: all
dependency-free items dispatch to a thread pool (each search is CPU-bound
numpy/Hungarian with jit'd cost evals that release the GIL), and a
completed producer immediately unlocks its consumers after its perm is
folded on the main thread.

Determinism: every item gets its own RNG derived from the base generator in
canonical item order, so results are independent of worker count and
completion order. One caveat: with a shared PermCache AND workers > 1,
items whose saliency matrices are byte-identical race to fill the same
cache slot, and which (equally valid) result wins depends on completion
order. `workers=1` (or REPRO_PERM_WORKERS=1) forces the fully serial,
fully deterministic path.
"""
from __future__ import annotations

import dataclasses
import os
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import module as nn
from repro.perm import propagate, realize
from repro.perm.cache import PermCache
from repro.perm.graph import (
    Container,
    EdgeKind,
    ModelPermGraph,
    PermNode,
    compile_model_graph,
    get_container,
    set_container,
)
from repro.perm.search import search_projection


@dataclasses.dataclass
class PruneReport:
    per_layer: list[tuple[str, float]] = dataclasses.field(default_factory=list)
    searches_run: int = 0
    cache_hits: int = 0

    @property
    def mean_retained(self) -> float:
        if not self.per_layer:
            return 1.0
        return float(np.mean([r for _, r in self.per_layer]))


def default_workers() -> int:
    env = os.environ.get("REPRO_PERM_WORKERS")
    if env:
        try:
            return max(1, int(env))
        except ValueError:
            raise ValueError(
                f"REPRO_PERM_WORKERS must be an integer, got {env!r}"
            ) from None
    return max(1, min(8, os.cpu_count() or 1))


def _saliency(wt: jnp.ndarray, fisher_t, saliency_kind: str) -> np.ndarray:
    if saliency_kind == "second_order" and fisher_t is not None:
        return np.asarray((wt.astype(jnp.float32) ** 2) * fisher_t, np.float32)
    return np.asarray(jnp.abs(wt), np.float32)


def _spawn_rngs(rng: np.random.Generator, n: int) -> list[np.random.Generator]:
    """Deterministic child generators; independent of completion order."""
    seeds = rng.integers(0, 2**63 - 1, size=n, dtype=np.uint64)
    return [np.random.default_rng(int(s)) for s in seeds]


@dataclasses.dataclass
class _LayerState:
    layer: dict                    # current (progressively folded) params
    fisher: dict | None
    tag: str
    results: dict[str, tuple]      # path -> (out_perm, col_order)


@dataclasses.dataclass(frozen=True)
class _Item:
    ci: int                        # container index
    li: int                        # layer index within the container stack
    path: str


class ModelPermEngine:
    """Runs the three phases for a whole model's params pytree."""

    def __init__(
        self,
        cfg,
        *,
        method: str = "gyro",
        rng: np.random.Generator | None = None,
        fisher=None,
        saliency_kind: str = "magnitude",
        ocp_iters: int = 8,
        icp_iters: int = 8,
        cache: PermCache | None = None,
        workers: int | None = None,
        graph: ModelPermGraph | None = None,
    ):
        if method not in ("gyro", "noperm", "icp_only", "ocp_only", "v1", "v2"):
            raise ValueError(f"unknown method {method!r}")
        self.cfg = cfg
        self.hcfg = cfg.hinm
        self.method = method
        self.rng = rng or np.random.default_rng(0)
        self.fisher = fisher
        self.saliency_kind = saliency_kind
        self.ocp_iters = ocp_iters
        self.icp_iters = icp_iters
        self.cache = cache
        self.workers = default_workers() if workers is None else max(1, workers)
        self.graph = graph or compile_model_graph(cfg)
        self.report = PruneReport()

    # -- phase 1+2: search with inline propagation ---------------------------

    def _search_one(self, node: PermNode, w, tied_ws, fisher_leaf,
                    rng: np.random.Generator, virtual: bool):
        """One work item: (possibly expert-stacked) projection search."""
        if node.is_tied_partner and not virtual:
            # rows already follow the tie source; identity OCP, own ICP
            can_rows, row_blocks = False, 1
        else:
            can_rows, row_blocks = node.can_permute_rows, node.row_blocks

        def one(wi, fi, tws):
            wt = jnp.asarray(wi).T
            sal = _saliency(wt, fi, self.saliency_kind)
            sal_rows = sal
            for tw in tws:
                sal_rows = np.concatenate(
                    [sal_rows, _saliency(jnp.asarray(tw).T, None, "magnitude")],
                    axis=1,
                )
            return search_projection(
                sal, sal_rows, self.hcfg, method=self.method,
                can_permute_rows=can_rows, row_blocks=row_blocks, rng=rng,
                ocp_iters=self.ocp_iters, icp_iters=self.icp_iters,
                cache=self.cache,
            )

        if w.ndim == 3:  # expert stack
            fts = [None if fisher_leaf is None else jnp.asarray(fisher_leaf[e]).T
                   for e in range(w.shape[0])]
            outs = [one(w[e], fts[e], [tw[e] for tw in tied_ws])
                    for e in range(w.shape[0])]
            return np.stack([o[0] for o in outs]), np.stack([o[1] for o in outs])
        ft = None if fisher_leaf is None else jnp.asarray(fisher_leaf).T
        return one(w, ft, tied_ws)

    def _snapshot(self, state: _LayerState, cgraph, path: str):
        """Collect the (already folded) inputs of one search item."""
        node = cgraph.nodes[path]
        w = nn.get_path(state.layer, path)["w"]
        tied_ws = [nn.get_path(state.layer, e.dst)["w"]
                   for e in cgraph.out_edges(path) if e.kind == EdgeKind.TIED]
        fisher_leaf = None
        if state.fisher is not None and self.saliency_kind == "second_order":
            fisher_leaf = nn.get_path(state.fisher, path)["w"]
        return node, w, tied_ws, fisher_leaf

    def _validate(self, node: PermNode, cgraph, perm, what: str):
        propagate.check_bijection(perm, what)
        for c in cgraph.constraints(node.path):
            if c.kind == EdgeKind.RESIDUAL:
                propagate.check_identity(perm, what)
            elif c.kind == EdgeKind.BLOCK_DIAGONAL and not node.is_tied_partner:
                propagate.check_block_diagonal(perm, node.row_blocks, what)

    def _fold(self, state: _LayerState, cgraph, path: str, perm):
        """Propagate a completed search along the node's out-edges."""
        layer = state.layer
        if propagate.is_identity(perm):
            return
        node_dict = dict(nn.get_path(layer, path))
        node_dict["w"] = propagate.permute_out(node_dict["w"], perm)
        if node_dict.get("b") is not None:
            node_dict["b"] = propagate.permute_bias(node_dict["b"], perm)
        layer = nn.set_path(layer, path, node_dict)
        for e in cgraph.out_edges(path):
            dn = dict(nn.get_path(layer, e.dst))
            if e.kind == EdgeKind.TIED:
                dn["w"] = propagate.permute_out(dn["w"], perm)
                if dn.get("b") is not None:
                    dn["b"] = propagate.permute_bias(dn["b"], perm)
            elif e.kind == EdgeKind.GQA_EXPAND:
                cperm = propagate.gqa_expand_perm(
                    perm, self.cfg.n_kv_heads, self.cfg.n_heads, self.cfg.head_dim
                )
                dn["w"] = propagate.permute_in(dn["w"], cperm)
            else:  # producer-rows → consumer-cols
                dn["w"] = propagate.permute_in(dn["w"], perm)
            layer = nn.set_path(layer, e.dst, dn)
        state.layer = layer

    def _run_items(self, states: dict[tuple[int, int], _LayerState],
                   containers: list[Container]):
        """Wavefront-schedule every (container, layer, node) search item."""
        items: list[_Item] = []
        deps: dict[_Item, set[_Item]] = {}
        dependents: dict[_Item, list[_Item]] = {}
        for (ci, li), state in states.items():
            cgraph = containers[ci].graph
            node_deps = cgraph.deps()
            for path in cgraph.topo_order():
                it = _Item(ci, li, path)
                items.append(it)
                dset = {_Item(ci, li, s) for s in node_deps[path]}
                deps[it] = dset
                for d in dset:
                    dependents.setdefault(d, []).append(it)
        rngs = dict(zip(items, _spawn_rngs(self.rng, len(items))))
        misses0 = self.cache.misses if self.cache else 0
        hits0 = self.cache.hits if self.cache else 0

        def task_args(it: _Item):
            state = states[(it.ci, it.li)]
            cgraph = containers[it.ci].graph
            node, w, tied_ws, fl = self._snapshot(state, cgraph, it.path)
            return state, cgraph, node, w, tied_ws, fl

        def complete(it: _Item, perm, col_order):
            state = states[(it.ci, it.li)]
            cgraph = containers[it.ci].graph
            node = cgraph.nodes[it.path]
            self._validate(node, cgraph, perm,
                           f"{state.tag}[{it.li}]/{it.path}")
            self._fold(state, cgraph, it.path, perm)
            state.results[it.path] = (perm, col_order)

        if self.workers <= 1:
            for it in items:
                state, cgraph, node, w, tied_ws, fl = task_args(it)
                perm, col = self._search_one(node, w, tied_ws, fl, rngs[it],
                                             virtual=False)
                complete(it, perm, col)
        else:
            remaining = {it: set(d) for it, d in deps.items()}
            futures = {}
            with ThreadPoolExecutor(max_workers=self.workers) as ex:
                def submit(it: _Item):
                    _, _, node, w, tied_ws, fl = task_args(it)
                    futures[ex.submit(self._search_one, node, w, tied_ws, fl,
                                      rngs[it], False)] = it

                for it in items:
                    if not remaining[it]:
                        submit(it)
                while futures:
                    done, _ = wait(futures, return_when=FIRST_COMPLETED)
                    for f in done:
                        it = futures.pop(f)
                        perm, col = f.result()
                        complete(it, perm, col)
                        for dep in dependents.get(it, ()):
                            remaining[dep].discard(it)
                            if not remaining[dep]:
                                submit(dep)

        if self.cache:
            self.report.cache_hits += self.cache.hits - hits0
            self.report.searches_run += self.cache.misses - misses0
        else:
            self.report.searches_run += len(items)

    # -- phase 3: realize ----------------------------------------------------

    def _realize_layer(self, state: _LayerState, cgraph):
        """Pack + mask every searched node of one folded layer."""
        layer = state.layer
        masks: dict[str, jnp.ndarray] = {}
        packs: dict[str, object] = {}
        identity = None
        for path in cgraph.order:
            perm, col_order = state.results[path]
            w = nn.get_path(layer, path)["w"]
            if w.ndim == 3:
                outs = [realize.realize_stored(w[e], np.arange(w.shape[2]),
                                               col_order[e], self.hcfg)
                        for e in range(w.shape[0])]
                new_w = jnp.stack([o[0] for o in outs])
                mask = jnp.stack([o[1] for o in outs])
                packed = jax.tree.map(lambda *xs: jnp.stack(xs),
                                      *[o[2] for o in outs])
                retained = float(np.mean([o[3] for o in outs]))
            else:
                identity = np.arange(w.shape[1])
                new_w, mask, packed, retained = realize.realize_stored(
                    w, identity, col_order, self.hcfg
                )
            layer = nn.set_path(layer, path,
                                {**nn.get_path(layer, path), "w": new_w})
            masks[path] = mask
            packs[path] = packed
            self.report.per_layer.append(
                (f"{state.tag}/{path}", retained)
            )
        # assemble mask / packed pytrees mirroring the (permuted) layer
        mask_tree = jax.tree.map(lambda x: None, layer,
                                 is_leaf=lambda x: not isinstance(x, dict))
        packed_tree = layer
        for path, m in masks.items():
            node = nn.get_path(layer, path)
            mask_tree = nn.set_path(
                mask_tree, path, {k: (m if k == "w" else None) for k in node}
            )
        for path, p in packs.items():
            node = dict(nn.get_path(layer, path))
            node["w"] = p
            packed_tree = nn.set_path(packed_tree, path, node)
        return layer, mask_tree, packed_tree

    # -- public entry points -------------------------------------------------

    def run_stacks(self, stacked_containers: dict[int, tuple]):
        """Physical pruning over {container_index: (layer_stack, fisher_stack)}.

        Returns {container_index: (params_stack, mask_stack, packed_stack)}.
        """
        states: dict[tuple[int, int], _LayerState] = {}
        counts: dict[int, int] = {}
        for ci, (stack, fstack) in stacked_containers.items():
            tag = self.graph.containers[ci].tag
            n = jax.tree.leaves(stack)[0].shape[0]
            counts[ci] = n
            for i in range(n):
                states[(ci, i)] = _LayerState(
                    layer=jax.tree.map(lambda a: a[i], stack),
                    fisher=None if fstack is None
                    else jax.tree.map(lambda a: a[i], fstack),
                    tag=f"{tag}[{i}]",
                    results={},
                )
        self._run_items(states, self.graph.containers)
        self.states = states  # searched perms, introspectable post-run

        out = {}
        for ci, n in counts.items():
            cgraph = self.graph.containers[ci].graph
            per_layer = [self._realize_layer(states[(ci, i)], cgraph)
                         for i in range(n)]
            out[ci] = _restack(per_layer)
        return out

    def run_virtual(self, params):
        """Mask-only pruning: searches in the ORIGINAL layout, masks mapped
        back through the inverse row perm; params untouched, no packing."""
        instances = list(self.graph.instances())
        rngs = _spawn_rngs(self.rng, len(instances))
        misses0 = self.cache.misses if self.cache else 0
        hits0 = self.cache.hits if self.cache else 0

        def one_instance(args):
            (key, sel, node), rng = args
            container = get_container(params, key, sel)
            w = nn.get_path(container, node.path)["w"]

            def one(wi):
                perm, col_order = self._search_one(
                    node, wi, [], None, rng, virtual=True
                )
                r = realize.realize_matrix(jnp.asarray(wi).T, perm, col_order,
                                           self.hcfg, pack=False)
                mask = realize.mask_to_original_rows(r.mask_p, perm, axis=0)
                return mask.T, r.retained

            lead = w.ndim - 2
            if lead == 0:
                return one(w)
            flat = w.reshape((-1,) + w.shape[-2:])
            outs = [one(flat[i]) for i in range(flat.shape[0])]
            mask = jnp.stack([o[0] for o in outs]).reshape(w.shape)
            return mask, float(np.mean([o[1] for o in outs]))

        work = list(zip(instances, rngs))
        if self.workers <= 1 or len(work) <= 1:
            results = [one_instance(a) for a in work]
        else:
            with ThreadPoolExecutor(max_workers=self.workers) as ex:
                results = list(ex.map(one_instance, work))

        masks = jax.tree.map(lambda x: None, params,
                             is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
        masks = dict(masks)
        for ((key, sel, node), _), (mask, retained) in zip(work, results):
            container = get_container(params, key, sel)
            pnode = nn.get_path(container, node.path)
            mcontainer = get_container(masks, key, sel)
            mcontainer = nn.set_path(
                mcontainer, node.path,
                {k: (mask if k == "w" else None) for k in pnode},
            )
            masks = set_container(masks, key, sel, mcontainer)
            self.report.per_layer.append((f"{key}/{node.path}", retained))
        if self.cache:
            self.report.cache_hits += self.cache.hits - hits0
            self.report.searches_run += self.cache.misses - misses0
        else:
            self.report.searches_run += len(work)
        return masks


def _restack(per_layer: list[tuple]):
    """Restack per-layer (params, masks, packed) trees along a new lead axis."""
    restacked = []
    for j in range(len(per_layer[0])):
        restacked.append(
            jax.tree.map(
                lambda *xs: None if xs[0] is None else jnp.stack(xs),
                *[o[j] for o in per_layer],
                is_leaf=lambda x: x is None,
            )
        )
    return tuple(restacked)
