"""PermGraph — declarative permutation-propagation for HiNM pruning.

A model's `hinm_plan` compiles into an explicit graph of prunable nodes and
typed coupling edges; pruning then runs as three separated phases (search,
propagate, realize) instead of one monolithic walker. See README.md in this
package for the architecture.
"""
from repro.perm.cache import PermCache
from repro.perm.engine import ModelPermEngine
from repro.perm.graph import (
    EdgeKind,
    LayerPermGraph,
    ModelPermGraph,
    PermEdge,
    PermNode,
    compile_model_graph,
)

__all__ = [
    "EdgeKind",
    "LayerPermGraph",
    "ModelPermEngine",
    "ModelPermGraph",
    "PermCache",
    "PermEdge",
    "PermNode",
    "compile_model_graph",
]
