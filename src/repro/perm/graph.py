"""Permutation-propagation graph: nodes, typed edges, plan compilation.

A `LayerPermGraph` is the per-layer-type template compiled from a list of
`PruneSpec`s. Nodes are prunable projections; edges carry the coupling
rules the old walker hardcoded:

  producer-rows→consumer-cols : the producer's output-row permutation is
                                folded into the consumer's input columns
                                (free at runtime via the consumer's vec_idx)
  tied                        : elementwise-coupled rows (SwiGLU gate/up)
                                share the producer's OCP perm; the tied
                                partner then runs its own identity-OCP
                                search on the folded weight
  gqa-expand                  : the producer's within-kv-head row perm is
                                expanded to the per-query-head column perm
                                of the consumer (GQA V → attention output)
  residual-identity           : residual-constrained rows — OCP is pinned
                                to identity and validated after search
  block-diagonal              : OCP restricted to contiguous row blocks
                                (head-structured outputs); validated to
                                never cross a block boundary

The model-level `ModelPermGraph` normalises the three plan shapes
(decoder-only list, per-pattern-position dict, enc/dec dict) into a list of
containers, each holding one layer template plus where its stacked params
live in the params pytree.
"""
from __future__ import annotations

import dataclasses


class EdgeKind:
    PRODUCER = "producer-rows→consumer-cols"
    TIED = "tied"
    GQA_EXPAND = "gqa-expand"
    RESIDUAL = "residual-identity"
    BLOCK_DIAGONAL = "block-diagonal"


# sentinel dst for constraint edges that do not couple two projections
RESIDUAL_SINK = "<residual>"


@dataclasses.dataclass(frozen=True)
class PermNode:
    """One prunable projection inside a layer.

    `can_permute_rows` / `row_blocks` describe the search freedom used for
    mask-only (virtual) pruning; for physical pruning a tied partner
    (`tied_to` set) is always searched with identity OCP because its rows
    were already permuted by its tie source.
    """

    path: str
    row_blocks: int = 1
    can_permute_rows: bool = True
    tied_to: str | None = None

    @property
    def is_tied_partner(self) -> bool:
        return self.tied_to is not None


@dataclasses.dataclass(frozen=True)
class PermEdge:
    src: str
    dst: str
    kind: str


@dataclasses.dataclass
class LayerPermGraph:
    """Template graph for one layer type (shared by every stacked layer)."""

    nodes: dict[str, PermNode]
    edges: list[PermEdge]
    order: list[str]  # node paths in plan order (producers before consumers)

    def coupling_edges(self) -> list[PermEdge]:
        """Edges whose dst search depends on the src perm being folded."""
        return [e for e in self.edges
                if e.kind in (EdgeKind.PRODUCER, EdgeKind.TIED, EdgeKind.GQA_EXPAND)]

    def out_edges(self, path: str) -> list[PermEdge]:
        return [e for e in self.coupling_edges() if e.src == path]

    def deps(self) -> dict[str, list[str]]:
        """path -> list of node paths whose search must complete first."""
        d: dict[str, list[str]] = {p: [] for p in self.nodes}
        for e in self.coupling_edges():
            d[e.dst].append(e.src)
        return d

    def constraints(self, path: str) -> list[PermEdge]:
        return [e for e in self.edges if e.src == path
                and e.kind in (EdgeKind.RESIDUAL, EdgeKind.BLOCK_DIAGONAL)]

    def validate(self) -> None:
        """Structural validation: endpoints exist, no coupling cycles, a
        node receives rows from at most one producer/tie source."""
        for e in self.coupling_edges():
            if e.src not in self.nodes:
                raise ValueError(f"edge source {e.src!r} is not a planned node")
            if e.dst not in self.nodes:
                raise ValueError(
                    f"{e.kind} edge {e.src!r} -> {e.dst!r}: consumer is not "
                    "a planned node (its columns would silently desync)"
                )
        deps = self.deps()
        for path, srcs in deps.items():
            if len(srcs) > 1:
                raise ValueError(
                    f"node {path!r} receives folds from multiple producers "
                    f"{srcs}: input-column ordering would be ambiguous"
                )
        # Kahn toposort over coupling edges; leftover nodes => cycle
        indeg = {p: len(s) for p, s in deps.items()}
        ready = [p for p, d in indeg.items() if d == 0]
        seen = 0
        while ready:
            n = ready.pop()
            seen += 1
            for e in self.out_edges(n):
                indeg[e.dst] -= 1
                if indeg[e.dst] == 0:
                    ready.append(e.dst)
        if seen != len(self.nodes):
            cyc = [p for p, d in indeg.items() if d > 0]
            raise ValueError(f"permutation-coupling cycle through {cyc}")

    def topo_order(self) -> list[str]:
        """Plan order filtered to a valid topological order (validated)."""
        deps = self.deps()
        done: set[str] = set()
        out: list[str] = []
        pending = list(self.order)
        while pending:
            progressed = False
            for p in list(pending):
                if all(s in done for s in deps[p]):
                    out.append(p)
                    done.add(p)
                    pending.remove(p)
                    progressed = True
            if not progressed:
                raise ValueError(f"unsatisfiable ordering for {pending}")
        return out


def compile_layer_graph(specs) -> LayerPermGraph:
    """Compile a list of PruneSpecs into a validated LayerPermGraph."""
    nodes: dict[str, PermNode] = {}
    edges: list[PermEdge] = []
    order: list[str] = []

    def add_node(node: PermNode):
        if node.path in nodes:
            raise ValueError(f"duplicate plan entry for {node.path!r}")
        nodes[node.path] = node
        order.append(node.path)

    for spec in specs:
        add_node(PermNode(spec.path, row_blocks=spec.row_blocks,
                          can_permute_rows=spec.can_permute_rows))
        if not spec.can_permute_rows:
            edges.append(PermEdge(spec.path, RESIDUAL_SINK, EdgeKind.RESIDUAL))
        if spec.row_blocks > 1:
            edges.append(PermEdge(spec.path, spec.path, EdgeKind.BLOCK_DIAGONAL))
        for t in spec.tied:
            # tied partners inherit the producer's *virtual* search freedom
            add_node(PermNode(t, row_blocks=spec.row_blocks,
                              can_permute_rows=spec.can_permute_rows,
                              tied_to=spec.path))
            edges.append(PermEdge(spec.path, t, EdgeKind.TIED))
        for cons in spec.consumers:
            cpath, _, mode = cons.partition(":")
            kind = EdgeKind.GQA_EXPAND if mode == "gqa" else EdgeKind.PRODUCER
            edges.append(PermEdge(spec.path, cpath, kind))

    g = LayerPermGraph(nodes=nodes, edges=edges, order=order)
    g.validate()
    return g


def get_container(tree, key, sel):
    """Address a container's subtree: tree[key] or tree[key][sel]."""
    node = tree[key]
    return node[sel] if sel is not None else node


def set_container(tree, key, sel, value):
    out = dict(tree)
    if sel is not None:
        lst = list(out[key])
        lst[sel] = value
        out[key] = lst
    else:
        out[key] = value
    return out


@dataclasses.dataclass(frozen=True)
class Container:
    """Where one layer template's stacked params live in the params tree.

    key/sel address the stacked subtree (params[key] or params[key][sel]);
    tag prefixes report entries ("enc", "stack0", "blocks").
    """

    key: str
    sel: int | None
    tag: str
    graph: LayerPermGraph


@dataclasses.dataclass
class ModelPermGraph:
    containers: list[Container]

    def instances(self):
        """Yield (key, sel, node) over every planned node, plan order."""
        for c in self.containers:
            for path in c.graph.order:
                yield c.key, c.sel, c.graph.nodes[path]


def compile_model_graph(cfg) -> ModelPermGraph:
    """Compile `zoo.hinm_plan(cfg)` into a ModelPermGraph."""
    from repro.models import zoo

    plan = zoo.hinm_plan(cfg)
    containers: list[Container] = []
    if isinstance(plan, dict) and "enc" in plan:
        for k in ("enc", "dec"):
            containers.append(Container(k, None, k, compile_layer_graph(plan[k])))
    elif isinstance(plan, dict):  # per-pattern-position stacks
        for j, specs in plan.items():
            containers.append(
                Container("stacks", j, f"stack{j}", compile_layer_graph(specs))
            )
    else:
        containers.append(Container("blocks", None, "blocks",
                                    compile_layer_graph(plan)))
    return ModelPermGraph(containers)
