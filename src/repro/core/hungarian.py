"""Offline combinatorial solvers used by gyro-permutation.

- `linear_sum_assignment`: Hungarian assignment. Uses scipy's C
  implementation when available, with a pure-numpy Jonker-Volgenant
  (shortest augmenting path) fallback so the core has no hard scipy
  dependency.
- `balanced_kmeans`: K-means with exact equal-size clusters, solved by
  turning the assignment step into a Hungarian problem over
  (points x cluster-slots) — the clustering used by the OCP phase [4].

Everything here is offline preprocessing (numpy, not jax).
"""
from __future__ import annotations

import numpy as np

try:  # pragma: no cover - exercised implicitly
    from scipy.optimize import linear_sum_assignment as _scipy_lsa
except Exception:  # pragma: no cover
    _scipy_lsa = None


def _lsa_numpy(cost: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Jonker-Volgenant shortest-augmenting-path LAP. cost: (n, n)."""
    cost = np.asarray(cost, dtype=np.float64)
    n = cost.shape[0]
    u = np.zeros(n + 1)
    v = np.zeros(n + 1)
    p = np.full(n + 1, 0, dtype=np.int64)   # p[j] = row matched to column j
    way = np.zeros(n + 1, dtype=np.int64)
    # 1-indexed classic implementation
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = np.full(n + 1, np.inf)
        used = np.zeros(n + 1, dtype=bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            delta = np.inf
            j1 = -1
            for j in range(1, n + 1):
                if not used[j]:
                    cur = cost[i0 - 1, j - 1] - u[i0] - v[j]
                    if cur < minv[j]:
                        minv[j] = cur
                        way[j] = j0
                    if minv[j] < delta:
                        delta = minv[j]
                        j1 = j
            for j in range(n + 1):
                if used[j]:
                    u[p[j]] += delta
                    v[j] -= delta
                else:
                    minv[j] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while True:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
            if j0 == 0:
                break
    col_of_row = np.zeros(n, dtype=np.int64)
    for j in range(1, n + 1):
        if p[j] > 0:
            col_of_row[p[j] - 1] = j - 1
    rows = np.arange(n)
    return rows, col_of_row


def linear_sum_assignment(cost: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Minimum-cost perfect matching on a square cost matrix."""
    cost = np.asarray(cost)
    if cost.ndim != 2 or cost.shape[0] != cost.shape[1]:
        raise ValueError(f"square cost matrix required, got {cost.shape}")
    if _scipy_lsa is not None:
        r, c = _scipy_lsa(cost)
        return np.asarray(r), np.asarray(c)
    return _lsa_numpy(cost)


def balanced_kmeans(
    points: np.ndarray,
    n_clusters: int,
    rng: np.random.Generator,
    n_iters: int = 8,
) -> np.ndarray:
    """Equal-size K-means. points: (P, d) with P % n_clusters == 0.

    Returns labels (P,) with exactly P / n_clusters points per cluster.
    The balanced assignment step replicates each centroid `capacity` times
    and solves a Hungarian matching of points to centroid slots.
    """
    points = np.asarray(points, dtype=np.float64)
    n_pts = points.shape[0]
    if n_pts % n_clusters != 0:
        raise ValueError(f"{n_pts} points not divisible by {n_clusters} clusters")
    cap = n_pts // n_clusters
    if n_clusters == 1:
        return np.zeros(n_pts, dtype=np.int64)

    # k-means++ style init
    centroids = points[rng.choice(n_pts, size=n_clusters, replace=False)]
    labels = np.zeros(n_pts, dtype=np.int64)
    for _ in range(n_iters):
        # squared distances (P, C)
        d2 = ((points[:, None, :] - centroids[None, :, :]) ** 2).sum(-1)
        slot_cost = np.repeat(d2, cap, axis=1)  # (P, C*cap)
        _, cols = linear_sum_assignment(slot_cost)
        new_labels = cols // cap
        if np.array_equal(new_labels, labels):
            labels = new_labels
            break
        labels = new_labels
        for c in range(n_clusters):
            centroids[c] = points[labels == c].mean(axis=0)
    return labels
