"""Baseline permutation/pruning strategies the paper compares against.

  - `ovw_ocp`        : OVW-style output-channel permutation [4] — one-shot
                       balanced K-means over *all* output channels (no
                       sampling, no Hungarian pruning-aware assignment).
                       Used for the HiNM-V1 ablation and the OVW baseline.
  - `apex_icp_tile`  : NVIDIA-Apex-style input-channel permutation [8] —
                       greedy column swaps between N:M partitions, adapted
                       to column-vector granularity. Used for HiNM-V2.
  - `ovw_prune`      : pure vector-wise sparsity at a given total sparsity
                       (the OVW curve in Figs. 3/4).
  - `unstructured`   : element-wise magnitude pruning (upper bound curve).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import sparsity
from repro.core.gyro import _nm_retained_groups, icp
from repro.core.hungarian import balanced_kmeans
from repro.core.types import GyroResult, HiNMConfig


def ovw_ocp(sal: np.ndarray, cfg: HiNMConfig, rng: np.random.Generator) -> np.ndarray:
    """One-shot balanced K-means OCP (OVW [4]): cluster all rows into tiles."""
    sal = np.asarray(sal, dtype=np.float32)
    n_out = sal.shape[0]
    p = n_out // cfg.v
    if p == 1:
        return np.arange(n_out)
    labels = balanced_kmeans(sal, p, rng)
    return np.argsort(labels, kind="stable")


def apex_icp_tile(
    tile: np.ndarray,
    cfg: HiNMConfig,
    rng: np.random.Generator,
    max_swaps: int = 2000,
) -> np.ndarray:
    """Greedy stochastic column-swap ICP (Apex-style [8]) on one (V, K) tile."""
    tile = np.asarray(tile, dtype=np.float32)
    v, k = tile.shape
    g = k // cfg.m
    order = np.arange(k)
    if g == 1:
        return order

    def part_ret(o: np.ndarray) -> float:
        grp = jnp.asarray(tile[:, o].reshape(v, g, cfg.m))
        return float(_nm_retained_groups(jnp.moveaxis(grp, 0, 1), cfg.n, cfg.m).sum())

    best = part_ret(order)
    for _ in range(max_swaps):
        a, b = rng.integers(0, k, size=2)
        if a // cfg.m == b // cfg.m:
            continue
        cand = order.copy()
        cand[a], cand[b] = cand[b], cand[a]
        r = part_ret(cand)
        if r > best + 1e-9:
            best, order = r, cand
    return order


def hinm_v1(
    sal: np.ndarray, cfg: HiNMConfig, rng: np.random.Generator, icp_iters: int = 16
) -> GyroResult:
    """Ablation HiNM-V1: OVW-style OCP + our ICP."""
    sal = np.asarray(sal, dtype=np.float32)
    out_perm = ovw_ocp(sal, cfg, rng)
    sal_p = sal[out_perm]
    col_ids = np.asarray(sparsity.kept_column_ids(jnp.asarray(sal_p), cfg))
    t, k = col_ids.shape
    gathered = np.take_along_axis(
        sal_p.reshape(t, cfg.v, -1), col_ids[:, None, :], axis=2
    )
    orders, _ = icp(gathered, cfg, iters=icp_iters)
    col_order = np.take_along_axis(col_ids, orders, axis=1)
    mask = sparsity.hinm_mask_from_columns(jnp.asarray(sal_p), jnp.asarray(col_order), cfg)
    retained = float(jnp.sum(jnp.asarray(sal_p) * mask))
    return GyroResult(out_perm, col_order.astype(np.int32), retained, float(sal.sum()))


def hinm_v2(
    sal: np.ndarray, cfg: HiNMConfig, rng: np.random.Generator, ocp_iters: int = 24
) -> GyroResult:
    """Ablation HiNM-V2: our OCP + Apex-style swap ICP."""
    from repro.core.gyro import ocp as our_ocp

    sal = np.asarray(sal, dtype=np.float32)
    out_perm, _ = our_ocp(sal, cfg, iters=ocp_iters, rng=rng)
    sal_p = sal[out_perm]
    col_ids = np.asarray(sparsity.kept_column_ids(jnp.asarray(sal_p), cfg))
    t, k = col_ids.shape
    gathered = np.take_along_axis(
        sal_p.reshape(t, cfg.v, -1), col_ids[:, None, :], axis=2
    )
    col_order = np.empty_like(col_ids)
    for ti in range(t):
        o = apex_icp_tile(gathered[ti], cfg, rng)
        col_order[ti] = col_ids[ti][o]
    mask = sparsity.hinm_mask_from_columns(jnp.asarray(sal_p), jnp.asarray(col_order), cfg)
    retained = float(jnp.sum(jnp.asarray(sal_p) * mask))
    return GyroResult(out_perm, col_order.astype(np.int32), retained, float(sal.sum()))


def ovw_prune(
    sal: np.ndarray, cfg_v: int, total_sparsity: float, rng: np.random.Generator
) -> float:
    """OVW baseline: vector-only sparsity at `total_sparsity` + k-means OCP.

    Returns retained saliency fraction.
    """
    sal = np.asarray(sal, dtype=np.float32)
    cfg = HiNMConfig(v=cfg_v, n=1, m=2, vector_sparsity=total_sparsity)
    # n=1,m=2 is a placeholder; vector-only retention only uses vector_mask.
    out_perm = ovw_ocp(sal, cfg, rng)
    sal_p = jnp.asarray(sal[out_perm])
    mask = sparsity.vector_mask(sal_p, cfg)
    return float(jnp.sum(sal_p * mask) / sal.sum())


def unstructured_retained(sal: np.ndarray, total_sparsity: float) -> float:
    sal_j = jnp.asarray(np.asarray(sal, dtype=np.float32))
    mask = sparsity.unstructured_mask(sal_j, total_sparsity)
    return float(jnp.sum(sal_j * mask) / sal_j.sum())
