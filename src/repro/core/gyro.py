"""Gyro-permutation (the paper's core algorithm, Section 4).

Two coupled searches, run offline on a per-layer saliency matrix:

  OCP  — output-channel permutation: groups the n_out rows into tiles of V
         so that column-wise vector pruning (followed by N:M) discards as
         little saliency as possible.  Iterates {sampling -> balanced
         K-means clustering -> Hungarian assignment} with an annealed
         sample count (the paper's learning-rate analogy).

  ICP  — tile-wise input-channel permutation: within each tile, permutes
         the K kept column-vectors across the K/M partitions of the N:M
         grouping so the 2:4 stage keeps the most saliency.  One sample
         per partition, no clustering, Hungarian assignment (Section 4.2).

Cost evaluation is the exact Eq. (4) objective and is jit/vmap-accelerated
(the combinatorial solvers stay in numpy — they are offline).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparsity
from repro.core.hungarian import balanced_kmeans, linear_sum_assignment
from repro.core.types import GyroResult, HiNMConfig

CostMode = Literal["hinm", "vector"]


# ---------------------------------------------------------------------------
# jit-accelerated cost kernels
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "cost_mode"))
def _tile_retained(tiles: jax.Array, cfg: HiNMConfig, cost_mode: str) -> jax.Array:
    """Retained saliency of each (V, n_in) tile under the target pattern.

    tiles: (B, V, n_in) -> (B,) retained saliency.
    """

    def one(tile):
        if cost_mode == "vector":
            mask = sparsity.vector_mask(tile, cfg)
        else:
            mask = sparsity.hinm_mask(tile, cfg)
        return jnp.sum(tile * mask)

    return jax.vmap(one)(tiles)


@functools.partial(jax.jit, static_argnames=("n", "m"))
def _nm_retained_groups(groups: jax.Array, n: int, m: int) -> jax.Array:
    """groups: (..., V, M) -> (...,) retained after per-row top-N of M."""
    top = jax.lax.top_k(groups, n)[0]
    return top.sum(axis=(-1, -2))


@functools.partial(jax.jit, static_argnames=("cfg",))
def _channel_pruned_saliency(sal_perm: jax.Array, cfg: HiNMConfig) -> jax.Array:
    """Per-output-channel saliency discarded by the current HiNM mask."""
    mask = sparsity.hinm_mask(sal_perm, cfg)
    return jnp.sum(sal_perm * (1.0 - mask), axis=1)


# ---------------------------------------------------------------------------
# OCP — output-channel permutation
# ---------------------------------------------------------------------------


def _sample_schedule(v: int, iters: int, s0: int | None = None) -> list[int]:
    """Annealed per-partition sample counts (learning-rate analogy)."""
    if s0 is None:
        s0 = max(1, v // 4)
    out = []
    for t in range(iters):
        frac = t / max(iters - 1, 1)
        s = int(round(s0 * (1.0 - frac) + 1 * frac))
        out.append(max(1, min(s, v)))
    return out


def ocp(
    sal: np.ndarray,
    cfg: HiNMConfig,
    iters: int = 24,
    rng: np.random.Generator | None = None,
    cost_mode: CostMode = "hinm",
    s0: int | None = None,
    patience: int = 6,
) -> tuple[np.ndarray, list[float]]:
    """Output-channel permutation search. Returns (perm (n_out,), history)."""
    rng = rng or np.random.default_rng(0)
    sal = np.asarray(sal, dtype=np.float32)
    n_out, n_in = sal.shape
    cfg.validate_shape(n_out, n_in)
    v = cfg.v
    p = n_out // v

    perm = np.arange(n_out)
    sal_j = jnp.asarray(sal)

    def total_retained(perm_np: np.ndarray) -> float:
        tiles = jnp.asarray(sal[perm_np].reshape(p, v, n_in))
        return float(_tile_retained(tiles, cfg, cost_mode).sum())

    best = total_retained(perm)
    history = [best]
    schedule = _sample_schedule(v, iters, s0)
    stall = 0

    for it, s in enumerate(schedule):
        if p == 1:
            break
        # ---- sampling: extract the s worst-fitting channels per partition
        sal_perm = jnp.take(sal_j, jnp.asarray(perm), axis=0)
        misfit = np.asarray(_channel_pruned_saliency(sal_perm, cfg))
        part = perm.reshape(p, v)
        part_misfit = misfit.reshape(p, v)
        # worst-fit with random tie-noise to escape plateaus
        noise = rng.uniform(0.0, 1e-6, size=part_misfit.shape) * (part_misfit.max() + 1.0)
        extract_pos = np.argsort(-(part_misfit + noise), axis=1)[:, :s]  # (P, s)
        extracted = np.take_along_axis(part, extract_pos, axis=1)        # (P, s)
        keep_mask = np.ones((p, v), dtype=bool)
        np.put_along_axis(keep_mask, extract_pos, False, axis=1)
        bases = part[keep_mask].reshape(p, v - s)                        # (P, V-s)

        # ---- clustering: balanced k-means of the P*s samples into P groups
        samples = extracted.reshape(-1)                                  # (P*s,)
        if s == 1:
            clusters = samples.reshape(p, 1)
        else:
            feats = sal[samples]
            labels = balanced_kmeans(feats, p, rng)
            order = np.argsort(labels, kind="stable")
            clusters = samples[order].reshape(p, s)                      # (P, s)

        # ---- assignment: Hungarian on exact Eq.(4) cost
        base_rows = sal[bases.reshape(-1)].reshape(p, v - s, n_in)
        clus_rows = sal[clusters.reshape(-1)].reshape(p, s, n_in)
        cost = np.empty((p, p), dtype=np.float64)
        totals = base_rows.sum(axis=(1, 2))[:, None] + clus_rows.sum(axis=(1, 2))[None, :]
        clus_j = jnp.asarray(clus_rows)
        for i in range(p):
            base_i = jnp.broadcast_to(jnp.asarray(base_rows[i])[None], (p, v - s, n_in))
            tiles = jnp.concatenate([base_i, clus_j], axis=1)            # (P, V, n_in)
            ret = np.asarray(_tile_retained(tiles, cfg, cost_mode))
            cost[i, :] = totals[i] - ret
        rows, cols = linear_sum_assignment(cost)

        new_part = np.concatenate([bases, clusters[cols]], axis=1)       # (P, V)
        new_perm = new_part.reshape(-1)
        cand = total_retained(new_perm)
        if cand > best + 1e-9:
            best, perm = cand, new_perm
            stall = 0
        else:
            stall += 1
        history.append(best)
        if stall >= patience:
            break
    return perm, history


# ---------------------------------------------------------------------------
# ICP — tile-wise input-channel (column-vector) permutation
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n", "m"))
def _icp_marginals(tile: jax.Array, n: int, m: int) -> jax.Array:
    """Marginal retained saliency of each column within its M-partition.

    tile: (V, K) -> (G, M) marginal of removing each column from its group.
    Smallest marginal = most replaceable = the ICP sample.
    """
    v, k = tile.shape
    g = k // m
    grp = tile.reshape(v, g, m)
    full = _nm_retained_groups(jnp.moveaxis(grp, 0, 1), n, m)            # (G,)

    def without(slot):
        reduced = jnp.delete(grp, slot, axis=2, assume_unique_indices=True)
        # after removing one column: keep top-N of the remaining M-1
        top = jax.lax.top_k(jnp.moveaxis(reduced, 0, 1), n)[0]
        return top.sum(axis=(-1, -2))                                    # (G,)

    rets = jnp.stack([without(sl) for sl in range(m)], axis=1)           # (G, M)
    return full[:, None] - rets


@functools.partial(jax.jit, static_argnames=("n", "m", "chunk"))
def _icp_cost_matrix(
    rem: jax.Array, cols: jax.Array, n: int, m: int, chunk: int = 64
) -> jax.Array:
    """Eq.(4) cost of placing extracted column j into partition i.

    rem:  (G, V, M-1) remaining columns per partition
    cols: (G, V)      extracted columns
    returns (G, G) cost = total - retained(top-N of M).
    """
    g = rem.shape[0]
    totals = rem.sum(axis=(1, 2))[:, None] + cols.sum(axis=1)[None, :]

    def row(rem_i):
        merged = jnp.concatenate(
            [jnp.broadcast_to(rem_i[None], (g,) + rem_i.shape), cols[:, :, None]],
            axis=2,
        )                                                                 # (G, V, M)
        return _nm_retained_groups(merged, n, m)                          # (G,)

    ret = jax.lax.map(row, rem, batch_size=chunk)                         # (G, G)
    return totals - ret


def icp_tile(
    tile: np.ndarray,
    cfg: HiNMConfig,
    iters: int = 16,
    patience: int = 4,
) -> tuple[np.ndarray, list[float]]:
    """Permute the K kept columns of one (V, K) tile. Returns (order, hist)."""
    tile = np.asarray(tile, dtype=np.float32)
    v, k = tile.shape
    g = k // cfg.m
    order = np.arange(k)

    def retained(o: np.ndarray) -> float:
        grp = jnp.asarray(tile[:, o].reshape(v, g, cfg.m))
        return float(_nm_retained_groups(jnp.moveaxis(grp, 0, 1), cfg.n, cfg.m).sum())

    best = retained(order)
    history = [best]
    if g == 1:
        return order, history
    stall = 0
    for _ in range(iters):
        cur = jnp.asarray(tile[:, order])
        marg = np.asarray(_icp_marginals(cur, cfg.n, cfg.m))              # (G, M)
        extract_slot = np.argmin(marg, axis=1)                            # (G,)
        pos = order.reshape(g, cfg.m)
        extracted_pos = np.take_along_axis(pos, extract_slot[:, None], axis=1)[:, 0]
        keep = np.ones((g, cfg.m), dtype=bool)
        np.put_along_axis(keep, extract_slot[:, None], False, axis=1)
        rem_pos = pos[keep].reshape(g, cfg.m - 1)

        rem = jnp.asarray(tile[:, rem_pos.reshape(-1)].reshape(v, g, cfg.m - 1))
        rem = jnp.moveaxis(rem, 0, 1)                                      # (G, V, M-1)
        cols = jnp.asarray(tile[:, extracted_pos]).T                       # (G, V)
        cost = np.asarray(_icp_cost_matrix(rem, cols, cfg.n, cfg.m))
        _, assign = linear_sum_assignment(cost)

        new_pos = np.concatenate([rem_pos, extracted_pos[assign][:, None]], axis=1)
        new_order = new_pos.reshape(-1)
        cand = retained(new_order)
        if cand > best + 1e-9:
            best, order = cand, new_order
            stall = 0
        else:
            stall += 1
        history.append(best)
        if stall >= patience:
            break
    return order, history


def icp(
    sal_gathered: np.ndarray,
    cfg: HiNMConfig,
    iters: int = 16,
) -> tuple[np.ndarray, list[float]]:
    """Run ICP on every tile. sal_gathered: (T, V, K) -> orders (T, K)."""
    t = sal_gathered.shape[0]
    orders = np.empty((t, sal_gathered.shape[2]), dtype=np.int64)
    history: list[float] = []
    for ti in range(t):
        orders[ti], h = icp_tile(sal_gathered[ti], cfg, iters=iters)
        history.append(h[-1])
    return orders, history


# ---------------------------------------------------------------------------
# full gyro-permutation
# ---------------------------------------------------------------------------


def gyro_permute(
    sal: np.ndarray,
    cfg: HiNMConfig,
    ocp_iters: int = 24,
    icp_iters: int = 16,
    rng: np.random.Generator | None = None,
    cost_mode: CostMode = "hinm",
    run_ocp: bool = True,
    run_icp: bool = True,
) -> GyroResult:
    """Full pipeline: OCP -> vector selection -> tile-wise ICP.

    Returns a GyroResult whose `col_order` is the absolute kept-column ids in
    ICP order — i.e. exactly the `vec_idx` the packed format stores.
    """
    rng = rng or np.random.default_rng(0)
    sal = np.asarray(sal, dtype=np.float32)
    n_out, n_in = sal.shape
    cfg.validate_shape(n_out, n_in)
    history: list[float] = []

    if run_ocp:
        out_perm, h = ocp(sal, cfg, iters=ocp_iters, rng=rng, cost_mode=cost_mode)
        history.extend(h)
    else:
        out_perm = np.arange(n_out)

    sal_p = sal[out_perm]
    col_ids = np.asarray(sparsity.kept_column_ids(jnp.asarray(sal_p), cfg))  # (T, K)
    t, k = col_ids.shape
    sal_t = sal_p.reshape(t, cfg.v, n_in)
    gathered = np.take_along_axis(sal_t, col_ids[:, None, :], axis=2)        # (T,V,K)

    if run_icp:
        orders, _ = icp(gathered, cfg, iters=icp_iters)
        col_order = np.take_along_axis(col_ids, orders, axis=1)
    else:
        col_order = col_ids

    mask = sparsity.hinm_mask_from_columns(
        jnp.asarray(sal_p), jnp.asarray(col_order), cfg
    )
    retained = float(jnp.sum(jnp.asarray(sal_p) * mask))
    history.append(retained)
    return GyroResult(
        out_perm=out_perm,
        col_order=col_order.astype(np.int32),
        retained=retained,
        total=float(sal.sum()),
        history=history,
    )
