"""HiNM sparsity core: masks, packing, gyro-permutation, baselines."""
from repro.core.api import PrunedLinear, masked_dense, prune_matrix
from repro.core.types import GyroResult, HiNMConfig, PackedHiNM

__all__ = [
    "GyroResult",
    "HiNMConfig",
    "PackedHiNM",
    "PrunedLinear",
    "masked_dense",
    "prune_matrix",
]
