"""Shared dataclasses for the HiNM sparsity core.

The packed HiNM format (see DESIGN.md §4):

  vals    (T, V, Kn)  surviving weight values, ICP-permuted column order
  vec_idx (T, K)      source input-channel of each kept column-vector per tile
  nm_idx  (T, V, Kn)  slot (0..M-1) of each surviving value inside its M-group

with T = n_out / V tiles, K kept column-vectors per tile, Kn = K*N/M
surviving values per row.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class HiNMConfig:
    """Static configuration of the hierarchical N:M sparsity pattern."""

    v: int = 32          # column-vector length (output-channel tile height)
    n: int = 2           # N of N:M (values kept per group)
    m: int = 4           # M of N:M (group size along kept columns)
    vector_sparsity: float = 0.5  # fraction of column-vectors pruned per tile

    def __post_init__(self) -> None:
        if self.v <= 0 or self.v % 8 != 0:
            raise ValueError(f"V must be a positive multiple of 8, got {self.v}")
        if not (0 < self.n < self.m):
            raise ValueError(f"need 0 < N < M, got N={self.n} M={self.m}")
        if not (0.0 <= self.vector_sparsity < 1.0):
            raise ValueError(f"vector_sparsity in [0,1), got {self.vector_sparsity}")

    @property
    def total_sparsity(self) -> float:
        """Overall fraction of zeroed weights, e.g. 0.75 for 50% + 2:4."""
        return 1.0 - (1.0 - self.vector_sparsity) * (self.n / self.m)

    def kept_columns(self, n_in: int) -> int:
        """K — kept column-vectors per tile; rounded to a multiple of M."""
        k = int(round(n_in * (1.0 - self.vector_sparsity)))
        k = max(self.m, (k // self.m) * self.m)
        if k > n_in:
            k = (n_in // self.m) * self.m
        return k

    def num_tiles(self, n_out: int) -> int:
        if n_out % self.v != 0:
            raise ValueError(f"n_out={n_out} not divisible by V={self.v}")
        return n_out // self.v

    def validate_shape(self, n_out: int, n_in: int) -> None:
        if n_out % self.v != 0:
            raise ValueError(f"n_out={n_out} % V={self.v} != 0")
        if n_in % self.m != 0:
            raise ValueError(f"n_in={n_in} % M={self.m} != 0")


@dataclasses.dataclass
class PackedHiNM:
    """A weight matrix in packed HiNM format (see module docstring)."""

    vals: Any      # (T, V, Kn) float
    vec_idx: Any   # (T, K) int32
    nm_idx: Any    # (T, V, Kn) int8
    n_out: int
    n_in: int
    config: HiNMConfig

    @property
    def k(self) -> int:
        return self.vec_idx.shape[-1]

    @property
    def kn(self) -> int:
        return self.vals.shape[-1]

    @property
    def t(self) -> int:
        return self.vals.shape[0]

    def packed_bytes(self) -> int:
        """HBM footprint of the packed representation."""
        vb = np.prod(self.vals.shape) * jnp.dtype(self.vals.dtype).itemsize
        ib = np.prod(self.vec_idx.shape) * 4
        nb = np.prod(self.nm_idx.shape) * 1
        return int(vb + ib + nb)

    def dense_bytes(self) -> int:
        lead = int(np.prod(self.vals.shape[:-3])) if len(self.vals.shape) > 3 else 1
        return int(lead * self.n_out * self.n_in * jnp.dtype(self.vals.dtype).itemsize)


# PackedHiNM participates in params pytrees (scan over stacked layers,
# pjit shardings on its array fields); shape/config ride along as metadata.
jax.tree_util.register_dataclass(
    PackedHiNM,
    data_fields=["vals", "vec_idx", "nm_idx"],
    meta_fields=["n_out", "n_in", "config"],
)


@dataclasses.dataclass
class GyroResult:
    """Output of a gyro-permutation search for one weight matrix."""

    out_perm: np.ndarray          # (n_out,) permutation of output channels
    col_order: np.ndarray         # (T, K) per-tile kept-column order (= vec_idx)
    retained: float               # final retained saliency  ||M . rho||
    total: float                  # total saliency  ||rho||
    history: list[float] = dataclasses.field(default_factory=list)

    @property
    def retained_fraction(self) -> float:
        return float(self.retained / max(self.total, 1e-30))
