"""HiNM mask construction — jit-friendly jnp implementations.

All functions operate on a *saliency* array `sal` of the same shape as the
weight (higher = more important) and return boolean keep-masks. They are the
single source of truth for the sparsity pattern; packing, the Pallas kernels
and the training-time masked-dense path are all validated against them.

Layout convention: weights are (n_out, n_in); column-wise V x 1 vectors run
along the output-channel axis (axis 0), N:M groups run along the
input-channel axis (axis 1) over the *kept* columns in their current order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import HiNMConfig


def nm_mask(sal: jax.Array, n: int = 2, m: int = 4, axis: int = -1) -> jax.Array:
    """Keep-mask for N:M sparsity along `axis` (top-N of every M group)."""
    if sal.shape[axis] % m != 0:
        raise ValueError(f"axis size {sal.shape[axis]} % M={m} != 0")
    sal = jnp.moveaxis(sal, axis, -1)
    shape = sal.shape
    g = sal.reshape(shape[:-1] + (shape[-1] // m, m))
    # rank within each group, descending saliency; keep rank < n
    order = jnp.argsort(g, axis=-1, descending=True)
    ranks = jnp.argsort(order, axis=-1)
    mask = (ranks < n).reshape(shape)
    return jnp.moveaxis(mask, -1, axis)


def vector_scores(sal: jax.Array, v: int) -> jax.Array:
    """(n_out, n_in) -> (T, n_in): per-tile column-vector saliency sums.

    Accumulated in f32 so the vector selection is invariant to the storage
    dtype (bf16 sums would reorder near-tied columns)."""
    n_out, n_in = sal.shape
    return sal.astype(jnp.float32).reshape(n_out // v, v, n_in).sum(axis=1)


def vector_mask(sal: jax.Array, cfg: HiNMConfig) -> jax.Array:
    """Keep-mask for per-tile top-K column-vector pruning. (n_out, n_in)."""
    n_out, n_in = sal.shape
    cfg.validate_shape(n_out, n_in)
    k = cfg.kept_columns(n_in)
    scores = vector_scores(sal, cfg.v)                      # (T, n_in)
    order = jnp.argsort(scores, axis=-1, descending=True)
    ranks = jnp.argsort(order, axis=-1)
    keep_cols = ranks < k                                    # (T, n_in)
    return jnp.repeat(keep_cols, cfg.v, axis=0)


def kept_column_ids(sal: jax.Array, cfg: HiNMConfig) -> jax.Array:
    """(T, K) ids of kept columns per tile, in ascending column order.

    Stable: among kept columns the original ordering is preserved, which is
    what the 'no permutation' baseline uses as its N:M grouping order.
    """
    n_out, n_in = sal.shape
    k = cfg.kept_columns(n_in)
    scores = vector_scores(sal, cfg.v)                      # (T, n_in)
    order = jnp.argsort(scores, axis=-1, descending=True)
    ranks = jnp.argsort(order, axis=-1)
    keep = ranks < k
    col_ids = jnp.broadcast_to(jnp.arange(n_in), scores.shape)
    # sort key: dropped columns pushed to the end, kept stay in column order
    key = jnp.where(keep, col_ids, n_in + col_ids)
    return jnp.sort(key, axis=-1)[:, :k].astype(jnp.int32)


def hinm_mask_from_columns(
    sal: jax.Array, col_ids: jax.Array, cfg: HiNMConfig
) -> jax.Array:
    """HiNM keep-mask given an explicit per-tile kept-column order.

    `col_ids` (T, K) defines both which columns survive vector pruning and
    the order in which they are grouped into M-groups for N:M pruning (the
    ICP degree of freedom). Returns a (n_out, n_in) boolean mask.
    """
    n_out, n_in = sal.shape
    t = cfg.num_tiles(n_out)
    k = col_ids.shape[-1]
    sal_t = sal.reshape(t, cfg.v, n_in)
    gathered = jnp.take_along_axis(sal_t, col_ids[:, None, :], axis=2)  # (T,V,K)
    nm = nm_mask(gathered, cfg.n, cfg.m, axis=-1)                       # (T,V,K)
    full = jnp.zeros((t, cfg.v, n_in), dtype=bool)
    full = jax.vmap(lambda f, m_, c: f.at[:, c].set(m_))(full, nm, col_ids)
    return full.reshape(n_out, n_in)


def hinm_mask(sal: jax.Array, cfg: HiNMConfig) -> jax.Array:
    """HiNM keep-mask in the current layout (no permutation search)."""
    return hinm_mask_from_columns(sal, kept_column_ids(sal, cfg), cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def retained_saliency(sal: jax.Array, cfg: HiNMConfig) -> jax.Array:
    """||M . rho|| for the current layout — the objective of Eq. (1)."""
    return jnp.sum(sal * hinm_mask(sal, cfg))


def unstructured_mask(sal: jax.Array, sparsity: float) -> jax.Array:
    """Global magnitude top-k keep-mask (the paper's 'Unstructured')."""
    total = sal.size
    keep = max(1, int(round(total * (1.0 - sparsity))))
    flat = sal.reshape(-1)
    thresh = jax.lax.top_k(flat, keep)[0][-1]
    return (sal >= thresh).reshape(sal.shape)


def apply_mask(w: jax.Array, mask: jax.Array) -> jax.Array:
    return w * mask.astype(w.dtype)
