"""Saliency (importance) scores for pruning decisions.

Two estimators, mirroring the paper's choices:
  - magnitude (L1) — used for the CNN/ResNet experiments [9];
  - second-order diagonal-Fisher — used for DeiT/BERT [12, 23, 24].
    rho_ij = w_ij^2 * F_ij, with F the empirical diagonal Fisher
    (mean of squared gradients over calibration batches). This is the
    standard diagonal OBS/OBD surrogate: the loss increase from zeroing
    w_ij is ~ 1/2 * H_ii * w_ij^2, with H_ii ~ F_ii.
"""
from __future__ import annotations

from typing import Callable, Iterable

import jax
import jax.numpy as jnp


def magnitude(w: jax.Array) -> jax.Array:
    return jnp.abs(w)


def second_order(w: jax.Array, fisher_diag: jax.Array) -> jax.Array:
    """Diagonal second-order saliency: w^2 * diag(F)."""
    return (w.astype(jnp.float32) ** 2) * fisher_diag


def fisher_diag(
    grad_fn: Callable[[jax.Array], dict],
    batches: Iterable,
) -> dict:
    """Accumulate the empirical diagonal Fisher over calibration batches.

    `grad_fn(batch)` must return a pytree of per-parameter gradients.
    Returns the same pytree with mean-of-squares leaves (float32).
    """
    acc = None
    count = 0
    for batch in batches:
        grads = grad_fn(batch)
        sq = jax.tree.map(lambda g: (g.astype(jnp.float32) ** 2), grads)
        acc = sq if acc is None else jax.tree.map(jnp.add, acc, sq)
        count += 1
    if acc is None:
        raise ValueError("fisher_diag needs at least one calibration batch")
    return jax.tree.map(lambda a: a / count, acc)


def saliency_for(w: jax.Array, kind: str = "magnitude", fisher: jax.Array | None = None) -> jax.Array:
    if kind == "magnitude":
        return magnitude(w)
    if kind == "second_order":
        if fisher is None:
            raise ValueError("second_order saliency requires a fisher diagonal")
        return second_order(w, fisher)
    raise ValueError(f"unknown saliency kind: {kind!r}")
