"""dense <-> packed HiNM conversion.

`pack` operates on a weight whose rows are already OCP-permuted; the column
order argument (`col_ids`, shape (T, K)) carries both the vector-pruning
selection and the ICP permutation, and is stored verbatim as `vec_idx` —
this is exactly the paper's trick: the runtime reorder is free because the
kernel's indexed gather uses `vec_idx` anyway.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sparsity
from repro.core.types import HiNMConfig, PackedHiNM


def pack(
    w: jax.Array,
    cfg: HiNMConfig,
    col_ids: jax.Array | None = None,
    sal: jax.Array | None = None,
) -> PackedHiNM:
    """Compress (n_out, n_in) -> PackedHiNM.

    If `col_ids` is None, the default (no-permutation) kept-column order is
    derived from `sal` (defaults to |w|).
    """
    n_out, n_in = w.shape
    cfg.validate_shape(n_out, n_in)
    if sal is None:
        sal = jnp.abs(w)
    if col_ids is None:
        col_ids = sparsity.kept_column_ids(sal, cfg)
    t = cfg.num_tiles(n_out)
    k = col_ids.shape[-1]
    g = k // cfg.m

    w_t = w.reshape(t, cfg.v, n_in)
    sal_t = sal.reshape(t, cfg.v, n_in)
    w_g = jnp.take_along_axis(w_t, col_ids[:, None, :], axis=2)      # (T,V,K)
    sal_g = jnp.take_along_axis(sal_t, col_ids[:, None, :], axis=2)  # (T,V,K)

    w_grp = w_g.reshape(t, cfg.v, g, cfg.m)
    sal_grp = sal_g.reshape(t, cfg.v, g, cfg.m)
    order = jnp.argsort(sal_grp, axis=-1, descending=True)           # (T,V,G,M)
    top = jnp.sort(order[..., : cfg.n], axis=-1)                     # ascending slots
    vals = jnp.take_along_axis(w_grp, top, axis=-1)                  # (T,V,G,N)

    kn = g * cfg.n
    return PackedHiNM(
        vals=vals.reshape(t, cfg.v, kn),
        vec_idx=col_ids.astype(jnp.int32),
        nm_idx=top.reshape(t, cfg.v, kn).astype(jnp.int8),
        n_out=n_out,
        n_in=n_in,
        config=cfg,
    )


def unpack(p: PackedHiNM) -> jax.Array:
    """Reconstruct the masked-dense (n_out, n_in) weight from packed form."""
    cfg = p.config
    t, v, kn = p.vals.shape
    g = kn // cfg.n
    k = g * cfg.m
    vals = p.vals.reshape(t, v, g, cfg.n)
    slots = p.nm_idx.reshape(t, v, g, cfg.n).astype(jnp.int32)
    grp = jnp.zeros((t, v, g, cfg.m), dtype=p.vals.dtype)
    grp = jax.vmap(jax.vmap(jax.vmap(lambda z, s, x: z.at[s].set(x))))(grp, slots, vals)
    cols = grp.reshape(t, v, k)
    full = jnp.zeros((t, v, p.n_in), dtype=p.vals.dtype)
    full = jax.vmap(lambda f, c, x: f.at[:, c].set(x))(full, p.vec_idx, cols)
    return full.reshape(p.n_out, p.n_in)


def pack_mask(p: PackedHiNM) -> jax.Array:
    """Boolean keep-mask implied by a packed tensor (for validation)."""
    ones = PackedHiNM(
        vals=jnp.ones_like(p.vals),
        vec_idx=p.vec_idx,
        nm_idx=p.nm_idx,
        n_out=p.n_out,
        n_in=p.n_in,
        config=p.config,
    )
    return unpack(ones) > 0
