"""Model-facing entry points for HiNM pruning with gyro-permutation.

Layer-coupling rules (DESIGN.md §4): OCP physically reorders a producer's
output rows; every consumer of those channels sees the permutation folded
into either (a) its own weight columns before its gyro search runs, or
(b) its `vec_idx` gather — which is free at runtime, the paper's key trick.
Residual-constrained rows (e.g. d_model projections) use identity OCP;
head-structured rows (e.g. V projections under RoPE attention) restrict OCP
to within-block permutations via `row_blocks`.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, packing, saliency as saliency_mod, sparsity
from repro.core.gyro import gyro_permute
from repro.core.types import GyroResult, HiNMConfig, PackedHiNM

Method = Literal["gyro", "noperm", "icp_only", "ocp_only", "v1", "v2"]


@dataclasses.dataclass
class PrunedLinear:
    """Result of pruning one (n_out, n_in) projection."""

    packed: PackedHiNM            # rows in out_perm order
    mask: jax.Array               # (n_out, n_in) keep-mask in ORIGINAL row order
    out_perm: np.ndarray          # (n_out,) row permutation applied before packing
    retained: float
    total: float

    @property
    def retained_fraction(self) -> float:
        return self.retained / max(self.total, 1e-30)


def _run_method(
    sal: np.ndarray,
    cfg: HiNMConfig,
    method: Method,
    rng: np.random.Generator,
    ocp_iters: int,
    icp_iters: int,
) -> GyroResult:
    if method == "gyro":
        return gyro_permute(sal, cfg, ocp_iters=ocp_iters, icp_iters=icp_iters, rng=rng)
    if method == "noperm":
        return gyro_permute(sal, cfg, rng=rng, run_ocp=False, run_icp=False)
    if method == "icp_only":
        return gyro_permute(sal, cfg, icp_iters=icp_iters, rng=rng, run_ocp=False)
    if method == "ocp_only":
        return gyro_permute(sal, cfg, ocp_iters=ocp_iters, rng=rng, run_icp=False)
    if method == "v1":
        return baselines.hinm_v1(sal, cfg, rng, icp_iters=icp_iters)
    if method == "v2":
        return baselines.hinm_v2(sal, cfg, rng, ocp_iters=ocp_iters)
    raise ValueError(f"unknown method {method!r}")


def prune_matrix(
    w: jax.Array,
    cfg: HiNMConfig,
    method: Method = "gyro",
    saliency_kind: str = "magnitude",
    fisher: jax.Array | None = None,
    rng: np.random.Generator | None = None,
    row_blocks: int = 1,
    ocp_iters: int = 24,
    icp_iters: int = 16,
) -> PrunedLinear:
    """Prune one projection to HiNM sparsity with the chosen permutation.

    `row_blocks` restricts OCP to permutations within `n_out / row_blocks`
    sized row blocks (block-diagonal permutation) — used for head-structured
    outputs where cross-head reordering would change semantics.
    """
    rng = rng or np.random.default_rng(0)
    n_out, n_in = w.shape
    cfg.validate_shape(n_out, n_in)
    if n_out % row_blocks != 0:
        raise ValueError(f"n_out={n_out} % row_blocks={row_blocks} != 0")
    bs = n_out // row_blocks
    if bs % cfg.v != 0:
        raise ValueError(f"row block {bs} % V={cfg.v} != 0")

    sal = np.asarray(
        saliency_mod.saliency_for(w, saliency_kind, fisher), dtype=np.float32
    )

    perms, col_orders, retained = [], [], 0.0
    for b in range(row_blocks):
        blk = sal[b * bs : (b + 1) * bs]
        res = _run_method(blk, cfg, method, rng, ocp_iters, icp_iters)
        perms.append(res.out_perm + b * bs)
        col_orders.append(res.col_order)
        retained += res.retained
    out_perm = np.concatenate(perms)
    col_order = jnp.asarray(np.concatenate(col_orders, axis=0))

    w_p = jnp.take(jnp.asarray(w), jnp.asarray(out_perm), axis=0)
    sal_p = jnp.asarray(sal[out_perm])
    packed = packing.pack(w_p, cfg, col_ids=col_order, sal=sal_p)
    mask_p = sparsity.hinm_mask_from_columns(sal_p, col_order, cfg)
    inv = np.argsort(out_perm)
    mask = jnp.take(mask_p, jnp.asarray(inv), axis=0)
    return PrunedLinear(
        packed=packed,
        mask=mask,
        out_perm=out_perm,
        retained=float(retained if row_blocks > 1 else jnp.sum(sal_p * mask_p)),
        total=float(sal.sum()),
    )


def masked_dense(w: jax.Array, pruned: PrunedLinear) -> jax.Array:
    """Weight with the HiNM mask applied, in original row order (training)."""
    return w * pruned.mask.astype(w.dtype)
