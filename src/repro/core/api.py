"""Model-facing entry points for HiNM pruning with gyro-permutation.

Layer-coupling rules (DESIGN.md §4): OCP physically reorders a producer's
output rows; every consumer of those channels sees the permutation folded
into either (a) its own weight columns before its gyro search runs, or
(b) its `vec_idx` gather — which is free at runtime, the paper's key trick.
Residual-constrained rows (e.g. d_model projections) use identity OCP;
head-structured rows (e.g. V projections under RoPE attention) restrict OCP
to within-block permutations via `row_blocks`.

Model-level coupling lives in `repro.perm` (the PermGraph engine); this
module is the single-matrix entry point sharing the same search and
realize phases.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import numpy as np

from repro.core import saliency as saliency_mod
from repro.core.types import HiNMConfig, PackedHiNM

Method = Literal["gyro", "noperm", "icp_only", "ocp_only", "v1", "v2"]


@dataclasses.dataclass
class PrunedLinear:
    """Result of pruning one (n_out, n_in) projection."""

    packed: PackedHiNM            # rows in out_perm order
    mask: jax.Array               # (n_out, n_in) keep-mask in ORIGINAL row order
    out_perm: np.ndarray          # (n_out,) row permutation applied before packing
    retained: float
    total: float

    @property
    def retained_fraction(self) -> float:
        return self.retained / max(self.total, 1e-30)


def prune_matrix(
    w: jax.Array,
    cfg: HiNMConfig,
    method: Method = "gyro",
    saliency_kind: str = "magnitude",
    fisher: jax.Array | None = None,
    rng: np.random.Generator | None = None,
    row_blocks: int = 1,
    ocp_iters: int = 24,
    icp_iters: int = 16,
    cache=None,
) -> PrunedLinear:
    """Prune one projection to HiNM sparsity with the chosen permutation.

    `row_blocks` restricts OCP to permutations within `n_out / row_blocks`
    sized row blocks (block-diagonal permutation) — used for head-structured
    outputs where cross-head reordering would change semantics. `cache` is
    an optional `repro.perm.PermCache`.
    """
    from repro.perm import realize as perm_realize
    from repro.perm.search import search_projection

    rng = rng or np.random.default_rng(0)
    n_out, n_in = w.shape
    cfg.validate_shape(n_out, n_in)
    if n_out % row_blocks != 0:
        raise ValueError(f"n_out={n_out} % row_blocks={row_blocks} != 0")
    bs = n_out // row_blocks
    if bs % cfg.v != 0:
        raise ValueError(f"row block {bs} % V={cfg.v} != 0")

    sal = np.asarray(
        saliency_mod.saliency_for(w, saliency_kind, fisher), dtype=np.float32
    )
    out_perm, col_order = search_projection(
        sal, sal, cfg, method=method, can_permute_rows=True,
        row_blocks=row_blocks, rng=rng, ocp_iters=ocp_iters,
        icp_iters=icp_iters, cache=cache,
    )

    # realize against the SEARCH saliency (fisher-informed when requested),
    # not the magnitude default of the model path
    r = perm_realize.realize_matrix(w, out_perm, col_order, cfg, sal=sal)
    mask = perm_realize.mask_to_original_rows(r.mask_p, out_perm, axis=0)
    total = float(sal.sum())
    return PrunedLinear(
        packed=r.packed,
        mask=mask,
        out_perm=out_perm,
        retained=r.retained * total,
        total=total,
    )


def masked_dense(w: jax.Array, pruned: PrunedLinear) -> jax.Array:
    """Weight with the HiNM mask applied, in original row order (training)."""
    return w * pruned.mask.astype(w.dtype)
