"""xLSTM (arXiv:2405.04517): alternating mLSTM and sLSTM blocks.

mLSTM — matrix-memory LSTM with exponential gating:
  C_t = f_t * C_{t-1} + i_t * (v_t k_t^T);  n_t = f_t * n_{t-1} + i_t * k_t
  h_t = (C_t q_t) / max(|n_t^T q_t|, 1)
per head, with stabilised exponential input gates. Parallelisable over the
sequence via a cumulative-log-gate formulation (implemented with an
associative scan over the per-step log f); this is the block we run for
long_500k decode (state is O(d_k * d_v), not O(S)).

sLSTM — scalar-memory LSTM with block-diagonal recurrent weights (one block
per head) and exponential gating; inherently sequential, implemented with
lax.scan over time.

Projections (q/k/v/out, gate pre-activations) are HiNM-prunable; the
per-channel gate/state parameters are not (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import module as nn
from repro.models.module import PruneSpec


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    ks = nn.split_keys(key, 6)
    return {
        "ln": L.norm_init(cfg),
        "wq": nn.dense_init(ks[0], d, d, cfg.dtype),
        "wk": nn.dense_init(ks[1], d, d, cfg.dtype),
        "wv": nn.dense_init(ks[2], d, d, cfg.dtype),
        "wi": nn.dense_init(ks[3], d, h, cfg.dtype, bias=True),   # input gate (per head)
        "wf": nn.dense_init(ks[4], d, h, cfg.dtype, bias=True),   # forget gate
        "wo_gate": nn.dense_init(ks[5], d, d, cfg.dtype, bias=True),
        "wout": nn.dense_init(nn.split_keys(key, 7)[6], d, d, cfg.dtype),
    }


def mlstm_block(params, cfg, x, cache=None):
    """x: (B,S,D). cache: {"c": (B,H,dk,dv), "n": (B,H,dk), "m": (B,H)}."""
    b, s, d = x.shape
    h = cfg.n_heads
    dk = d // h
    inp = L.norm(params["ln"], x, cfg)
    q = nn.linear(params["wq"], inp).reshape(b, s, h, dk)
    k = nn.linear(params["wk"], inp).reshape(b, s, h, dk) * (dk ** -0.5)
    v = nn.linear(params["wv"], inp).reshape(b, s, h, dk)
    logi = nn.linear(params["wi"], inp).astype(jnp.float32)          # (B,S,H)
    logf = jax.nn.log_sigmoid(nn.linear(params["wf"], inp).astype(jnp.float32))

    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    if cache is None:
        c0 = jnp.zeros((b, h, dk, dk), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = (cache["c"].astype(jnp.float32), cache["n"].astype(jnp.float32),
                      cache["m"].astype(jnp.float32))

    def step(carry, t):
        c, n, m = carry
        qi, ki, vi, ii, fi = t
        m_new = jnp.maximum(fi + m, ii)                              # (B,H)
        fg = jnp.exp(fi + m - m_new)[..., None]
        ig = jnp.exp(ii - m_new)[..., None]
        c = c * fg[..., None] + ig[..., None] * (ki[..., :, None] * vi[..., None, :])
        n = n * fg + ig * ki
        num = jnp.einsum("bhkv,bhk->bhv", c, qi)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qi)), 1.0)
        out = num / den[..., None]
        return (c, n, m_new), out

    # (S, B, H, dk) ordering for all per-step tensors
    xs = (
        jnp.einsum("bshk->sbhk", qf),
        jnp.einsum("bshk->sbhk", kf),
        jnp.einsum("bshk->sbhk", vf),
        jnp.einsum("bsh->sbh", logi),
        jnp.einsum("bsh->sbh", logf),
    )
    from repro.models import probe_mode

    (c, n, m), outs = jax.lax.scan(step, (c0, n0, m0), xs,
                                   unroll=True if probe_mode.enabled() else 1)
    out = jnp.einsum("sbhv->bshv", outs).reshape(b, s, d)
    gate = jax.nn.sigmoid(nn.linear(params["wo_gate"], inp).astype(jnp.float32))
    y = nn.linear(params["wout"], (out * gate).astype(x.dtype))
    new_cache = None
    if cache is not None:
        new_cache = {"c": c, "n": n, "m": m}
    return x + y, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = nn.split_keys(key, 6)
    return {
        "ln": L.norm_init(cfg),
        "wz": nn.dense_init(ks[0], d, d, cfg.dtype, bias=True),
        "wi": nn.dense_init(ks[1], d, d, cfg.dtype, bias=True),
        "wf": nn.dense_init(ks[2], d, d, cfg.dtype, bias=True),
        "wo": nn.dense_init(ks[3], d, d, cfg.dtype, bias=True),
        # block-diagonal recurrent weights: (H, dh, dh) per gate
        "r": jax.random.normal(ks[4], (4, h, dh, dh), cfg.dtype) * (dh ** -0.5),
        "wout": nn.dense_init(ks[5], d, d, cfg.dtype),
    }


def slstm_block(params, cfg, x, cache=None):
    """x: (B,S,D). cache: {"c","n","h","m": (B,D) / (B,H)}. Sequential scan."""
    b, s, d = x.shape
    h_heads = cfg.n_heads
    dh = d // h_heads
    inp = L.norm(params["ln"], x, cfg)
    z_in = nn.linear(params["wz"], inp).astype(jnp.float32)
    i_in = nn.linear(params["wi"], inp).astype(jnp.float32)
    f_in = nn.linear(params["wf"], inp).astype(jnp.float32)
    o_in = nn.linear(params["wo"], inp).astype(jnp.float32)
    r = params["r"].astype(jnp.float32)                              # (4,H,dh,dh)

    if cache is None:
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.ones((b, d), jnp.float32)
        h0 = jnp.zeros((b, d), jnp.float32)
        m0 = jnp.zeros((b, d), jnp.float32)
    else:
        c0, n0, h0, m0 = (cache[k].astype(jnp.float32) for k in ("c", "n", "h", "m"))

    def rec(hprev):  # (B, D) -> per-gate recurrent contribution (4, B, D)
        hh = hprev.reshape(b, h_heads, dh)
        return jnp.einsum("bhi,ghio->gbho", hh, r).reshape(4, b, d)

    def step(carry, t):
        c, n, hprev, m = carry
        zi, ii, fi, oi = t
        rz, ri, rf, ro = rec(hprev)
        z = jnp.tanh(zi + rz)
        logf = jax.nn.log_sigmoid(fi + rf)
        logi = ii + ri
        m_new = jnp.maximum(logf + m, logi)
        fg = jnp.exp(logf + m - m_new)
        ig = jnp.exp(logi - m_new)
        c = fg * c + ig * z
        n = fg * n + ig
        hv = jax.nn.sigmoid(oi + ro) * (c / jnp.maximum(n, 1.0))
        return (c, n, hv, m_new), hv

    xs = tuple(jnp.einsum("bsd->sbd", t) for t in (z_in, i_in, f_in, o_in))
    from repro.models import probe_mode

    (c, n, hv, m), outs = jax.lax.scan(step, (c0, n0, h0, m0), xs,
                                       unroll=True if probe_mode.enabled() else 1)
    out = jnp.einsum("sbd->bsd", outs).astype(x.dtype)
    y = nn.linear(params["wout"], out)
    new_cache = None
    if cache is not None:
        new_cache = {"c": c, "n": n, "h": hv, "m": m}
    return x + y, new_cache


def xlstm_plan_specs(kind: str) -> list[PruneSpec]:
    if kind == "mlstm":
        return [
            PruneSpec("wq", can_permute_rows=False),
            PruneSpec("wk", can_permute_rows=False),
            PruneSpec("wv", can_permute_rows=False),
            PruneSpec("wo_gate", can_permute_rows=False),
            PruneSpec("wout", can_permute_rows=False),
        ]
    return [
        PruneSpec(name, can_permute_rows=False)
        for name in ("wz", "wi", "wf", "wo", "wout")
    ]
