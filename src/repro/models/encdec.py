"""Encoder-decoder transformer (seamless-m4t backbone).

The speech frontend is a stub per the assignment: `input_specs()` provides
precomputed frame embeddings (B, T_enc, D) straight into the encoder.
Decoder layers: causal self-attention (RoPE) + cross-attention to the
encoder output (no positional rotation) + FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import module as nn
from repro.models import paging
from repro.models.module import PruneSpec

# the decoder is pure attention (self + cross), so decoder-prompt rows can
# be bucketed with sentinel-position masking; encoder frames stay exact
BUCKETED_PREFILL = True
# decoder self-attention pages into the shared pool (cross-attention reads
# the fixed enc_out stripe), so the paged-attention kernel applies
PAGED_ATTN_KERNEL = True


def init_enc_layer(key, cfg):
    ks = nn.split_keys(key, 2)
    return {
        "ln1": L.norm_init(cfg),
        "attn": L.attention_init(ks[0], cfg),
        "ln2": L.norm_init(cfg),
        "mlp": L.mlp_init(ks[1], cfg),
    }


def init_dec_layer(key, cfg):
    ks = nn.split_keys(key, 3)
    return {
        "ln1": L.norm_init(cfg),
        "attn": L.attention_init(ks[0], cfg),
        "ln_x": L.norm_init(cfg),
        "xattn": L.attention_init(ks[1], cfg),
        "ln2": L.norm_init(cfg),
        "mlp": L.mlp_init(ks[2], cfg),
    }


def init(key, cfg):
    n_enc = cfg.n_enc_layers or cfg.n_layers
    ks = nn.split_keys(key, n_enc + cfg.n_layers + 3)
    enc = [init_enc_layer(ks[i], cfg) for i in range(n_enc)]
    dec = [init_dec_layer(ks[n_enc + i], cfg) for i in range(cfg.n_layers)]
    return {
        "frontend_proj": nn.dense_init(ks[-3], cfg.d_model, cfg.d_model, cfg.dtype),
        "embed": nn.embed_init(ks[-2], cfg.vocab_padded, cfg.d_model, cfg.dtype),
        "enc": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "ln_enc": L.norm_init(cfg),
        "ln_f": L.norm_init(cfg),
        "lm_head": nn.dense_init(ks[-1], cfg.d_model, cfg.vocab_padded, cfg.dtype),
    }


def _cross_attention(params, x, enc_out, cfg, enc_len=None):
    """Standard cross-attention: queries from x, keys/values from enc_out.

    `enc_len` (B,) masks padded encoder rows when `enc_out` comes from the
    fixed-width decode cache (serve slot pool): valid rows get key position
    0 and queries sit at 0, so the causal mask reduces to a bidirectional
    attend-over-valid."""
    b, s, _ = x.shape
    t = enc_out.shape[1]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = nn.linear(params["wq"], x).reshape(b, s, h, hd)
    k = nn.linear(params["wk"], enc_out).reshape(b, t, kvh, hd)
    v = nn.linear(params["wv"], enc_out).reshape(b, t, kvh, hd)
    qp = jnp.zeros((b, s), jnp.int32)
    if enc_len is None:
        kp = jnp.zeros((b, t), jnp.int32)
        out = L._attn_chunked(q, k, v, qp, kp, causal=False, window=0)
    else:
        kp = jnp.where(jnp.arange(t, dtype=jnp.int32)[None, :] < enc_len[:, None],
                       0, 2**30)
        out = L._attn_chunked(q, k, v, qp, kp, causal=True, window=0)
    return nn.linear(params["wo"], out.reshape(b, s, h * hd))


def encode(params, cfg, frames: jax.Array, remat: bool = True):
    """frames: (B, T, D) stub embeddings -> encoder output (B, T, D)."""
    x = nn.linear(params["frontend_proj"], frames.astype(cfg.dtype))
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def body(carry, lp):
        x = nn.constrain_batch(carry)
        h, _ = L.attention(lp["attn"], L.norm(lp["ln1"], x, cfg), positions, cfg,
                           bidirectional=True)
        x = x + h
        x = x + L.mlp(lp["mlp"], L.norm(lp["ln2"], x, cfg), cfg)
        return x, None

    from repro.models import probe_mode

    probing = probe_mode.enabled()
    fn = jax.checkpoint(body) if (remat and not probing) else body
    x, _ = jax.lax.scan(fn, x, params["enc"], unroll=True if probing else 1)
    return L.norm(params["ln_enc"], x, cfg)


def _dec_stack(params, cfg, x, positions, enc_out, caches=None, remat: bool = True,
               enc_len=None, spec: bool = False):
    def body(carry, layer):
        x = nn.constrain_batch(carry)
        lp, lc = layer if caches is not None else (layer, None)
        h, nc = L.attention(lp["attn"], L.norm(lp["ln1"], x, cfg), positions,
                            cfg, lc, spec=spec)
        x = x + h
        x = x + _cross_attention(lp["xattn"], L.norm(lp["ln_x"], x, cfg), enc_out,
                                 cfg, enc_len=enc_len)
        x = x + L.mlp(lp["mlp"], L.norm(lp["ln2"], x, cfg), cfg)
        return x, nc

    from repro.models import probe_mode

    probing = probe_mode.enabled()
    fn = jax.checkpoint(body) if (remat and not probing) else body
    xs = params["dec"] if caches is None else (params["dec"], caches)
    return jax.lax.scan(fn, x, xs, unroll=True if probing else 1)


def forward(params, cfg, tokens, embeds=None, remat: bool = True):
    """Training: embeds = frame stub (B, T_enc, D); tokens = decoder input."""
    if embeds is None:
        raise ValueError("enc-dec forward requires frontend frame embeddings")
    enc_out = encode(params, cfg, embeds, remat=remat)
    x = nn.embed(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, _ = _dec_stack(params, cfg, x, positions, enc_out, remat=remat)
    return L.norm(params["ln_f"], x, cfg)


def logits_fn(params, x):
    return nn.linear(params["lm_head"], x)


def make_cache(cfg, batch: int, max_seq: int, dtype=None, t_enc: int | None = None,
               page=None, n_pages=None):
    dtype = dtype or cfg.dtype
    t_enc = t_enc or max_seq
    if page is not None:
        geom = page_geometry(cfg, max_seq, page)
        self_c = paging.make_attn_pool(cfg.n_layers, n_pages, geom["page"],
                                       cfg.n_kv_heads, cfg.head_dim, dtype)
        self_c["pos"] = jnp.zeros((cfg.n_layers, batch), jnp.int32)
        self_c.update(paging.make_tables(cfg.n_layers, batch, geom["n_bt"]))
    else:
        self_c = {
            "k": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
            "pos": jnp.zeros((cfg.n_layers, batch), jnp.int32),
            "kpos": jnp.full((cfg.n_layers, batch, max_seq), 2**30, jnp.int32),
        }
    return {
        "self": self_c,
        "enc_out": jnp.zeros((batch, t_enc, cfg.d_model), dtype),
        # valid rows of enc_out per slot (a request's encoder output may be
        # shorter than the pool's fixed t_enc; the rest is masked)
        "enc_len": jnp.zeros((batch,), jnp.int32),
    }


def page_geometry(cfg, max_seq: int, page: int) -> dict:
    """Only decoder self-attn K/V is paged; the cached encoder output is a
    fixed-width per-slot stripe (one write at admission, read-only after)."""
    return paging.geometry(max_seq, page)


def paged_insert(cfg, pool, stripe, slot, row, scatter_ids, bt_row, n_alloc):
    return {
        "self": paging.insert_attn(pool["self"], stripe["self"], row,
                                   scatter_ids, bt_row, n_alloc, slot),
        "enc_out": paging.copy_slot_row(pool["enc_out"], stripe["enc_out"],
                                        slot, row, 0),
        "enc_len": paging.copy_slot_row(pool["enc_len"], stripe["enc_len"],
                                        slot, row, 0),
    }


def paged_release(cfg, pool, slot, page_ids):
    return {
        "self": paging.release_attn(pool["self"], page_ids, slot),
        "enc_out": paging.reset_slot_row(pool["enc_out"], slot, 0),
        "enc_len": paging.reset_slot_row(pool["enc_len"], slot, 0),
    }


def cache_batch_axes(cfg, cache):
    """Slot (batch) axis per cache leaf: decoder self-attn leaves are
    (L, B, ...); the cached encoder output and its length are (B, ...).
    Paged self-attn pool leaves map to None (no slot axis)."""
    if paging.is_paged(cache["self"]):
        self_axes = paging.paged_axes(cache["self"])
    else:
        self_axes = jax.tree.map(lambda _: 1, cache["self"])
    return {
        "self": self_axes,
        "enc_out": 0,
        "enc_len": 0,
    }


def cache_shard_roles(cfg, cache):
    """Sharding role per cache leaf: decoder self-attn like the decoder-only
    stack (paged pools page-axis, stripes slot-axis); the cached encoder
    output/length are per-slot encoder leaves (batch at axis 0)."""
    if paging.is_paged(cache["self"]):
        self_roles = paging.paged_roles(cache["self"])
    else:
        self_roles = {"k": "kv", "v": "kv", "pos": "slot", "kpos": "slot"}
    return {"self": self_roles, "enc_out": "enc", "enc_len": "enc"}


def prefill(params, cfg, tokens, cache, embeds=None, n_rows=None):
    b = tokens.shape[0]
    if embeds is not None:
        enc_out = encode(params, cfg, embeds)
        enc_len = jnp.full((b,), enc_out.shape[1], jnp.int32)
    else:
        enc_out, enc_len = cache["enc_out"], cache["enc_len"]
    x = nn.embed(params["embed"], tokens)
    s = x.shape[1]
    ar = jnp.arange(s, dtype=jnp.int32)
    if n_rows is None:
        positions = jnp.broadcast_to(ar, (b, s))
    else:
        # bucketed decoder prompt: padded rows carry the sentinel position,
        # so their cached kpos masks them out of every future attend
        positions = jnp.where(ar[None, :] < n_rows[:, None], ar[None, :],
                              paging.KPOS_SENTINEL)
    x, new_self = _dec_stack(params, cfg, x, positions, enc_out,
                             caches=cache["self"], enc_len=enc_len)
    x = L.norm(params["ln_f"], x, cfg)
    if n_rows is None:
        last = x[:, -1]
    else:
        last = jnp.take_along_axis(x, (n_rows - 1)[:, None, None], axis=1)[:, 0]
        new_self = dict(new_self, pos=jnp.broadcast_to(
            n_rows[None, :].astype(jnp.int32), new_self["pos"].shape))
    new_cache = {"self": new_self, "enc_out": enc_out, "enc_len": enc_len}
    return last, new_cache


def decode_step(params, cfg, tokens, cache):
    x = nn.embed(params["embed"], tokens)
    pos = cache["self"]["pos"][0]               # (B,) per-slot positions
    positions = pos.astype(jnp.int32)[:, None]
    x, new_self = _dec_stack(params, cfg, x, positions, cache["enc_out"],
                             caches=cache["self"], enc_len=cache["enc_len"])
    x = L.norm(params["ln_f"], x, cfg)
    return logits_fn(params, x[:, 0]), {"self": new_self, "enc_out": cache["enc_out"],
                                        "enc_len": cache["enc_len"]}


# serve/spec: the decoder is pure attention (self + cross), so one parallel
# forward verifies all candidate rows; cross-attention reads only the
# per-slot cached encoder output, which speculation never mutates
SPEC_VERIFY = "parallel"


def cache_position(cfg, cache):
    return cache["self"]["pos"][0]


def verify_step(params, cfg, tokens, cache):
    """Speculative verify over the decoder: see transformer.verify_step."""
    b, s = tokens.shape
    x = nn.embed(params["embed"], tokens)
    pos = cache["self"]["pos"][0]
    positions = pos.astype(jnp.int32)[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    x, new_self = _dec_stack(params, cfg, x, positions, cache["enc_out"],
                             caches=cache["self"], enc_len=cache["enc_len"],
                             spec=True)
    x = L.norm(params["ln_f"], x, cfg)
    new_cache = {"self": new_self, "enc_out": cache["enc_out"],
                 "enc_len": cache["enc_len"]}
    return logits_fn(params, x), new_cache, None


def cache_rollback(cfg, cache, undo, pos0, keep, n_written):
    roll = (paging.rollback_attn_paged if paging.is_paged(cache["self"])
            else paging.rollback_attn_stripe)
    return {"self": roll(cache["self"], pos0, keep, n_written,
                         window=bool(cfg.window)),
            "enc_out": cache["enc_out"], "enc_len": cache["enc_len"]}


def hinm_plan(cfg):
    def attn_specs(prefix):
        return [
            PruneSpec(f"{prefix}/wq", can_permute_rows=False),
            PruneSpec(f"{prefix}/wk", can_permute_rows=False),
            PruneSpec(f"{prefix}/wv", row_blocks=cfg.n_kv_heads,
                      consumers=(f"{prefix}/wo:gqa",)),
            PruneSpec(f"{prefix}/wo", can_permute_rows=False),
        ]

    mlp_specs = [
        PruneSpec("mlp/wg", tied=("mlp/wu",), consumers=("mlp/wd",)),
        PruneSpec("mlp/wd", can_permute_rows=False),
    ] if cfg.act == "swiglu" else [
        PruneSpec("mlp/wu", consumers=("mlp/wd",)),
        PruneSpec("mlp/wd", can_permute_rows=False),
    ]
    return {
        "enc": attn_specs("attn") + mlp_specs,
        "dec": attn_specs("attn") + attn_specs("xattn") + mlp_specs,
    }
