"""Shared transformer layers: RoPE, GQA attention (chunked online-softmax),
MLPs. All pure jnp; memory-bounded attention via a lax.scan over KV blocks
so 32k-sequence training shapes compile without materialising (S, S) scores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import module as nn
from repro.models import probe_mode

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd), positions: (B, S) -> rotated x."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x32a, x32b = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x32a * cos - x32b * sin, x32b * cos + x32a * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention — chunked online softmax (flash-style in pure jnp)
# ---------------------------------------------------------------------------


def _seq_parallel_decode_attn(q, ck, cv, q_pos, kpos, window: int):
    """Sequence-parallel decode attention (beyond-paper §Perf).

    The KV cache is S-sharded over 'model'; each shard computes attention
    over its local slots and the shards combine with a max/sum-stat psum —
    O(B*H*hd) bytes instead of all-gathering the cache (GBs per layer).
    Returns None when preconditions fail (no mesh / S doesn't divide).
    """
    from repro import compat

    am = compat.get_abstract_mesh()
    if am is None or am.empty or "model" not in am.axis_names:
        return None
    b, sq, h, hd = q.shape
    smax, kv = ck.shape[1], ck.shape[2]
    nmodel = am.shape["model"]
    if sq != 1 or smax % nmodel or smax // nmodel < 1:
        return None
    if kpos.ndim != 2:  # legacy shared-position caches are not supported
        return None
    dp = tuple(a for a in ("pod", "data") if a in am.axis_names)
    ndp = 1
    for a in dp:
        ndp *= am.shape[a]
    row = dp if (dp and b % ndp == 0) else None
    g = h // kv
    P = jax.sharding.PartitionSpec

    def body(q_l, k_l, v_l, kpos_l, qpos_l):
        bl = q_l.shape[0]  # local batch shard
        qf = (q_l.astype(jnp.float32) * hd ** -0.5).reshape(bl, kv, g, hd)
        kf = k_l.astype(jnp.float32)                      # (B, S_loc, KV, hd)
        s = jnp.einsum("bkgd,bckd->bkgc", qf, kf)         # (B, KV, G, S_loc)
        msk = kpos_l <= qpos_l[:, :1]                     # (B, S_loc) per slot
        if window:
            msk &= kpos_l > (qpos_l[:, :1] - window)
        s = jnp.where(msk[:, None, None, :], s, NEG_INF)
        m_l = jnp.max(s, axis=-1)
        m_g = jax.lax.pmax(m_l, "model")
        p = jnp.exp(s - m_g[..., None])
        l_g = jax.lax.psum(p.sum(-1), "model")
        o_g = jax.lax.psum(
            jnp.einsum("bkgc,bckd->bkgd", p, v_l.astype(jnp.float32)), "model"
        )
        out = o_g / jnp.maximum(l_g[..., None], 1e-30)
        return out.reshape(bl, 1, h, hd).astype(q_l.dtype)

    return jax.shard_map(
        body,
        mesh=am,
        in_specs=(P(row, None, None, None), P(row, "model", None, None),
                  P(row, "model", None, None), P(row, "model"), P(row, None)),
        out_specs=P(row, None, None, None),
        check_vma=False,
    )(q, ck, cv, kpos, q_pos)


def _attn_qchunk(
    qf: jax.Array,           # (B, Sq, KV, G, hd) f32, pre-scaled
    kb: jax.Array,           # (B, nblk, blk, KV, hd) f32
    vb: jax.Array,
    q_pos: jax.Array,        # (B, Sq)
    pb: jax.Array,           # (B, nblk, blk)
    causal: bool,
    window: int,
) -> jax.Array:
    """Online-softmax over KV blocks for one query chunk."""
    b, sq, kv, g, hd = qf.shape
    kv_block = kb.shape[2]

    def step(carry, blk):
        m_prev, l_prev, o_prev = carry
        kc, vc, pc = blk                                   # (B, blk, KV, hd) ...
        kc = kc.astype(jnp.float32)                        # per-block upcast only
        vc = vc.astype(jnp.float32)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qf, kc)        # (B,Sq,KV,G,blk)
        msk = pc[:, None, :] <= q_pos[:, :, None] if causal else jnp.ones(
            (b, sq, kv_block), dtype=bool
        )
        if window:
            msk &= pc[:, None, :] > (q_pos[:, :, None] - window)
        s = jnp.where(msk[:, :, None, None, :], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1)
        o_new = o_prev * corr[..., None] + jnp.einsum("bqkgc,bckd->bqkgd", p, vc)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, sq, kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kv, g), jnp.float32)
    o0 = jnp.zeros((b, sq, kv, g, hd), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        step,
        (m0, l0, o0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.moveaxis(pb, 1, 0)),
        unroll=True if probe_mode.enabled() else 1,
    )
    return o / jnp.maximum(l[..., None], 1e-30)


def _attn_chunked(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Sk, KV, hd)
    v: jax.Array,            # (B, Sk, KV, hd)
    q_pos: jax.Array,        # (B, Sq) absolute positions of queries
    k_pos: jax.Array,        # (B, Sk) absolute positions of keys
    causal: bool,
    window: int,             # 0 = unlimited
    kv_block: int = 512,
    q_block: int = 512,
    aligned: bool = False,   # q_pos/k_pos are the standard arange (training)
) -> jax.Array:
    """Flash-style attention in pure jnp: lax.map over query blocks, online
    softmax over KV blocks inside — peak memory is one (qblk, kvblk) score
    tile per device, never (S, S)."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, kv, g, hd)

    from repro.perf_knobs import KNOBS

    skip_blocks = causal and aligned and KNOBS.causal_block_skip
    if probe_mode.enabled() and not skip_blocks:
        # one-shot: every FLOP visible to cost_analysis. The block-skipping
        # path instead keeps its static chunking (whose skipped blocks ARE
        # the true FLOP count) with the inner scans unrolled.
        kv_block, q_block = sk, sq

    nblk = max(1, sk // kv_block)
    if sk % kv_block != 0:
        nblk, kv_block = 1, sk
    kb = k.reshape(b, nblk, kv_block, kv, hd)   # stays in storage dtype;
    vb = v.reshape(b, nblk, kv_block, kv, hd)   # upcast happens per block
    pb = k_pos.reshape(b, nblk, kv_block)

    nq = max(1, sq // q_block)
    if sq % q_block != 0:
        nq, q_block = 1, sq
    if nq == 1:
        out = _attn_qchunk(qf, kb, vb, q_pos, pb, causal, window)
        return out.reshape(b, sq, h, hd).astype(q.dtype)

    qc = jnp.moveaxis(qf.reshape(b, nq, q_block, kv, g, hd), 1, 0)
    pc = jnp.moveaxis(q_pos.reshape(b, nq, q_block), 1, 0)

    if skip_blocks:
        # causal block skipping (§Perf): positions are the standard arange,
        # so query chunk i attends to a STATIC prefix of KV blocks — the
        # upper-triangle blocks are never computed (2x attention FLOPs on
        # long-sequence training). Python loop => static slices,
        # differentiable; per-chunk checkpoint keeps flash-bwd memory.
        from functools import partial

        @partial(jax.checkpoint, static_argnums=(2, 3))
        def one_prefix(qi, pi, lo, hi, kbf, vbf, pbf):
            # slice INSIDE the remat region: the residual is the shared
            # full K/V (one buffer), not per-chunk slice copies
            return _attn_qchunk(qi, kbf[:, lo:hi], vbf[:, lo:hi], pi,
                                pbf[:, lo:hi], True, window)

        outs = []
        for i in range(nq):
            hi = min(nblk, ((i + 1) * q_block + kv_block - 1) // kv_block)
            lo = 0
            if window:
                lo = max(0, (i * q_block - window) // kv_block)
            outs.append(one_prefix(qc[i], pc[i], lo, hi, kb, vb, pb))
        out = jnp.stack(outs)                               # (nq, B, qblk, KV, G, hd)
        out = jnp.moveaxis(out, 0, 1).reshape(b, sq, h, hd)
        return out.astype(q.dtype)

    # checkpoint per query chunk: the backward pass recomputes each chunk's
    # probabilities instead of saving the full (S, S) tensor (flash bwd)
    @jax.checkpoint
    def one(args):
        qi, pi = args
        return _attn_qchunk(qi, kb, vb, pi, pb, causal, window)

    out = jax.lax.map(one, (qc, pc))                        # (nq, B, qblk, KV, G, hd)
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def attention(
    params: dict,
    x: jax.Array,                 # (B, S, D)
    positions: jax.Array,         # (B, S)
    cfg,
    cache: dict | None = None,    # decode: {"k","v" (B,Smax,KV,hd),
                                  #          "pos" (B,), "kpos" (B,Smax)}
    kv_block: int = 1024,
    bidirectional: bool = False,
    spec: bool = False,           # multi-token speculative verify write
) -> tuple[jax.Array, dict | None]:
    """GQA attention with RoPE. Returns (out (B,S,D), updated cache)."""
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = nn.linear(params["wq"], x).reshape(b, s, h, hd)
    k = nn.linear(params["wk"], x).reshape(b, s, kvh, hd)
    v = nn.linear(params["wv"], x).reshape(b, s, kvh, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = _attn_chunked(
            q, k, v, positions, positions, not bidirectional, cfg.window, kv_block,
            aligned=not bidirectional,
        )
        new_cache = None
    elif "bt" in cache:
        # Paged pool (serve slot pool): the slot's rows live in shared
        # physical pages resolved through its block table `bt` — a runtime
        # vec_idx for the cache. The new row is written straight to its
        # physical page; the gather `pool[bt]` then yields a contiguous
        # lane view for the same chunked attention as the stripe path.
        # Writes whose logical page falls outside the slot's allocation
        # (idle lanes keep stepping inside a decode chunk) are redirected
        # to the scratch page, which no block table ever references.
        from repro.models import paging

        if s != 1 and not spec:
            raise ValueError(
                "paged KV caches only support single-token decode here; "
                "multi-token writes go through the speculative verify "
                "branch (zoo.verify_step passes spec=True) and prefill "
                "runs on a stripe template")
        pos = cache["pos"]                                  # (B,) int32
        bt, alloc = cache["bt"], cache["alloc"]
        page = cache["k"].shape[1]                          # (n_pages, page, KV, hd)
        if s > 1 and cfg.window:
            # a wrapped multi-token write would clobber rows earlier
            # queries still need (hybrid verifies sequentially instead)
            raise ValueError("multi-token spec write cannot wrap a "
                             "windowed ring; use sequential verify")
        # single-token decode and speculative verify share one addressing
        # (also the sweep addressing of paging.rollback_attn_paged): all s
        # rows land through the block table in one dispatch, rows past the
        # allocation redirected to scratch.  For s > 1 the causal mask then
        # hides each row's future rows exactly, so one attend sees the same
        # KV set — in the same layout order, hence bitwise the same online
        # softmax — as s sequential single-token steps would.
        phys_s, off, valid = paging.spec_row_locations(
            bt, alloc, pos, s, page, window=bool(cfg.window))
        phys_w = jnp.where(valid, phys_s, paging.SCRATCH_PAGE)
        ck = cache["k"].at[phys_w, off].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[phys_w, off].set(v.astype(cache["v"].dtype))
        ckpos = cache["kpos"].at[phys_w, off].set(positions.astype(jnp.int32))
        from repro.perf_knobs import KNOBS

        out = None
        if KNOBS.paged_attn != "off":
            # Pallas kernel: resolves the block table inside the grid —
            # sentinel pages and swept rows mask through the same kpos
            # comparisons, so no gather copy is ever built. Returns None
            # when the backend defers to the gather path (auto off-TPU).
            from repro.kernels import ops as kops

            out = kops.paged_attention(q, ck, cv, ckpos, bt, positions,
                                       window=cfg.window,
                                       backend=KNOBS.paged_attn)
        if out is None:
            k_view = paging.gather_view(ck, bt)
            v_view = paging.gather_view(cv, bt)
            kpos_view = paging.gather_view(ckpos, bt)
            out = _attn_chunked(q, k_view, v_view, positions, kpos_view,
                                True, cfg.window, kv_block)
        new_cache = {"k": ck, "v": cv, "kpos": ckpos, "pos": pos + s,
                     "bt": bt, "alloc": alloc}
    else:
        # Cache slots are a ring buffer when a sliding window bounds the
        # live KV set (smax = window); per-slot absolute positions ("kpos")
        # drive the causal/window mask, so slot index never aliases time.
        # `pos` and `kpos` carry a batch dimension — each batch lane is an
        # independent request slot (continuous batching): lanes may sit at
        # different decode positions, so every cache write is a per-lane
        # dynamic_update_slice at that lane's own offset.
        pos = cache["pos"]                                  # (B,) int32
        smax = cache["k"].shape[1]
        if s >= smax:
            # prefill longer than the (windowed) cache: attend over the fresh
            # K/V directly and retain only the trailing `smax` entries,
            # rolled so the ring invariant slot == pos % smax holds for the
            # decode steps that follow.
            out = _attn_chunked(q, k, v, positions, positions, True, cfg.window, kv_block)
            shift = jax.lax.rem(positions[:, -smax].astype(jnp.int32), smax)
            ck = jax.vmap(lambda kb, sh: jnp.roll(kb, sh, axis=0))(
                k[:, -smax:].astype(cache["k"].dtype), shift)
            cv = jax.vmap(lambda vb, sh: jnp.roll(vb, sh, axis=0))(
                v[:, -smax:].astype(cache["v"].dtype), shift)
            new_kpos = jax.vmap(jnp.roll)(
                positions[:, -smax:].astype(jnp.int32), shift)
        elif spec and s > 1:
            # speculative verify on a stripe: scatter the s candidate rows
            # at each lane's own offsets (rows past the stripe end are
            # dropped by the scatter — they can only be over-reservation
            # rows the acceptance cap already rejects), then attend once
            # with causal masking.  Same bitwise-equivalence argument as
            # the paged spec write; windowed rings verify sequentially.
            if cfg.window:
                raise ValueError("multi-token spec write cannot wrap a "
                                 "windowed ring; use sequential verify")
            idx = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            bidx = jnp.arange(b)[:, None]
            ck = cache["k"].at[bidx, idx].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[bidx, idx].set(v.astype(cache["v"].dtype))
            new_kpos = cache["kpos"].at[bidx, idx].set(
                positions.astype(jnp.int32))
            out = _attn_chunked(q, ck, cv, positions, new_kpos, True,
                                cfg.window, kv_block)
        else:
            slot = jax.lax.rem(pos, smax) if cfg.window else pos
            ck = jax.vmap(
                lambda cb, kb, st: jax.lax.dynamic_update_slice(cb, kb, (st, 0, 0))
            )(cache["k"], k.astype(cache["k"].dtype), slot)
            cv = jax.vmap(
                lambda cb, vb, st: jax.lax.dynamic_update_slice(cb, vb, (st, 0, 0))
            )(cache["v"], v.astype(cache["v"].dtype), slot)
            new_kpos = jax.vmap(
                lambda kp, pr, st: jax.lax.dynamic_update_slice(kp, pr, (st,))
            )(cache["kpos"], positions.astype(jnp.int32), slot)
            from repro.perf_knobs import KNOBS

            out = None
            if s == 1 and KNOBS.seq_parallel_decode:
                out = _seq_parallel_decode_attn(q, ck, cv, positions, new_kpos,
                                                cfg.window)
            if out is None:
                out = _attn_chunked(q, ck, cv, positions, new_kpos, True,
                                    cfg.window, kv_block)
        new_cache = {"k": ck, "v": cv, "pos": pos + s, "kpos": new_kpos}
    out = out.reshape(b, s, h * hd)
    return nn.linear(params["wo"], out), new_cache


def attention_init(key, cfg, d_in: int | None = None):
    d = d_in or cfg.d_model
    ks = nn.split_keys(key, 4)
    return {
        "wq": nn.dense_init(ks[0], d, cfg.attn_out_dim, cfg.dtype, bias=cfg.qkv_bias),
        "wk": nn.dense_init(ks[1], d, cfg.kv_out_dim, cfg.dtype, bias=cfg.qkv_bias),
        "wv": nn.dense_init(ks[2], d, cfg.kv_out_dim, cfg.dtype, bias=cfg.qkv_bias),
        "wo": nn.dense_init(ks[3], cfg.attn_out_dim, cfg.d_model, cfg.dtype),
    }


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg, d_ff: int | None = None):
    f = d_ff or cfg.d_ff
    ks = nn.split_keys(key, 3)
    if cfg.act == "swiglu":
        return {
            "wg": nn.dense_init(ks[0], cfg.d_model, f, cfg.dtype),
            "wu": nn.dense_init(ks[1], cfg.d_model, f, cfg.dtype),
            "wd": nn.dense_init(ks[2], f, cfg.d_model, cfg.dtype),
        }
    return {
        "wu": nn.dense_init(ks[0], cfg.d_model, f, cfg.dtype, bias=True),
        "wd": nn.dense_init(ks[1], f, cfg.d_model, cfg.dtype, bias=True),
    }


def mlp(params: dict, x: jax.Array, cfg) -> jax.Array:
    if cfg.act == "swiglu":
        gate = jax.nn.silu(nn.linear(params["wg"], x).astype(jnp.float32))
        up = nn.linear(params["wu"], x).astype(jnp.float32)
        return nn.linear(params["wd"], (gate * up).astype(x.dtype))
    h = jax.nn.gelu(nn.linear(params["wu"], x).astype(jnp.float32))
    return nn.linear(params["wd"], h.astype(x.dtype))


def norm_init(cfg, d: int | None = None):
    d = d or cfg.d_model
    return nn.rmsnorm_init(d, cfg.dtype) if cfg.norm == "rmsnorm" else nn.layernorm_init(d, cfg.dtype)


def norm(params, x, cfg):
    return nn.rmsnorm(params, x) if cfg.norm == "rmsnorm" else nn.layernorm(params, x)
