"""RecurrentGemma / Griffin-style hybrid: RG-LRU recurrent blocks
interleaved with local (sliding-window) attention, pattern (rec, rec, attn).

RG-LRU (Griffin, arXiv:2402.19427): a diagonal gated linear recurrence
  r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
  a_t = a^(c * r_t)            (a = sigmoid(Lambda), per-channel)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
computed with an associative scan over time (sub-quadratic; the reason
this arch runs the long_500k decode cell).

The recurrence block wraps the RG-LRU with in/out projections and a short
depthwise temporal conv, following Griffin's recurrent block. The diagonal
gate parameters (Lambda, conv filters) are per-channel vectors — not
matmuls — and are exempt from HiNM (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import module as nn
from repro.models.module import PruneSpec

C_SCALE = 8.0
CONV_K = 4


def rglru_block_init(key, cfg):
    d, r = cfg.d_model, cfg.rglru_dim or cfg.d_model
    ks = nn.split_keys(key, 5)
    return {
        "ln": L.norm_init(cfg),
        "win": nn.dense_init(ks[0], d, r, cfg.dtype),       # input branch
        "wgate": nn.dense_init(ks[1], d, r, cfg.dtype),     # multiplicative branch
        "conv": jax.random.normal(ks[2], (CONV_K, r), cfg.dtype) * 0.02,
        "wa": nn.dense_init(ks[3], r, r, cfg.dtype),        # recurrence gate
        "wx": nn.dense_init(ks[4], r, r, cfg.dtype),        # input gate
        "lam": jnp.full((r,), 2.0, jnp.float32),            # a = sigmoid(lam)
        "wout": nn.dense_init(nn.split_keys(key, 6)[5], r, d, cfg.dtype),
    }


def _rglru_scan(x: jax.Array, a_t: jax.Array, h0: jax.Array):
    """h_t = a_t * h_{t-1} + x_t via associative scan. x,a_t: (B,S,R)."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_seq, b_seq = jax.lax.associative_scan(combine, (a_t, x), axis=1)
    return a_seq * h0[:, None, :] + b_seq


def rglru_block(params, cfg, x, cache=None):
    """x: (B, S, D); cache: {"h": (B,R), "conv": (B,CONV_K-1,R)} or None."""
    inp = L.norm(params["ln"], x, cfg)
    u = nn.linear(params["win"], inp)                        # (B,S,R)
    gate_branch = jax.nn.gelu(nn.linear(params["wgate"], inp).astype(jnp.float32))

    # short causal depthwise conv over time
    if cache is None:
        pad = jnp.zeros((u.shape[0], CONV_K - 1, u.shape[2]), u.dtype)
        hist = jnp.concatenate([pad, u], axis=1)
        conv_prev = None
    else:
        hist = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)
        conv_prev = hist[:, -(CONV_K - 1):, :]
    w = params["conv"].astype(jnp.float32)
    uc = sum(
        hist[:, i : i + u.shape[1], :].astype(jnp.float32) * w[i]
        for i in range(CONV_K)
    )

    r = jax.nn.sigmoid(nn.linear(params["wa"], uc.astype(u.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid(nn.linear(params["wx"], uc.astype(u.dtype)).astype(jnp.float32))
    log_a = -C_SCALE * jax.nn.softplus(-params["lam"]) * r   # log a_t <= 0
    a_t = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a_t**2, 1e-9)) * (i * uc.astype(jnp.float32))

    h0 = cache["h"].astype(jnp.float32) if cache is not None else jnp.zeros(
        (u.shape[0], u.shape[2]), jnp.float32
    )
    h = _rglru_scan(gated_x, a_t, h0)                        # (B,S,R)
    out = (h * gate_branch).astype(x.dtype)
    y = nn.linear(params["wout"], out)
    new_cache = None
    if cache is not None:
        new_cache = {"h": h[:, -1, :], "conv": conv_prev.astype(cache["conv"].dtype)}
    return x + y, new_cache


def rglru_plan_specs(prefix: str = "") -> list[PruneSpec]:
    # The R channels are threaded through per-channel gates (lam, conv) and
    # an elementwise product of two branches — permuting any projection's
    # rows would require rewriting all of them plus the vector params, so
    # the recurrent block is ICP-only (OCP identity). See DESIGN.md §6.
    p = prefix
    return [
        PruneSpec(f"{p}{name}", can_permute_rows=False)
        for name in ("win", "wgate", "wa", "wx", "wout")
    ]
