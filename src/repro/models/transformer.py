"""Decoder-only transformer (covers qwen2.5-14b, starcoder2-15b, qwen2-0.5b,
codeqwen1.5-7b, and the phi-3-vision / MoE backbones).

Layer stack is scan-compatible: params are stacked over the layer dimension
and the forward pass runs `jax.lax.scan` over layers (with optional remat),
keeping HLO size independent of depth — essential for the 48-64L dry-runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import module as nn
from repro.models import paging
from repro.models.module import PruneSpec

# pure-attention prefill: padded rows are exactly masked (sentinel kpos),
# so prompts can be bucketed to power-of-two lengths (serve admission)
BUCKETED_PREFILL = True
# the paged decode cache is the shared (n_pages, page, KV, hd) pool, so
# the Pallas paged-attention kernel can resolve it (kernels/paged_attn)
PAGED_ATTN_KERNEL = True
# K/V rows are pure per-(token, position) projections here — an identical
# token prefix at identical positions caches bitwise-identical rows — so
# physical pages can be refcount-shared across slots (serve/prefix)
PREFIX_SHARE = True


def init_block(key, cfg):
    ks = nn.split_keys(key, 2)
    p = {
        "ln1": L.norm_init(cfg),
        "attn": L.attention_init(ks[0], cfg),
        "ln2": L.norm_init(cfg),
    }
    if cfg.family == "moe":
        from repro.models import moe

        p["moe"] = moe.moe_init(ks[1], cfg)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg)
    return p


def block(params, cfg, x, positions, cache=None, spec=False):
    x = nn.constrain_batch(x)
    h, new_cache = L.attention(params["attn"], L.norm(params["ln1"], x, cfg),
                               positions, cfg, cache, spec=spec)
    x = x + h
    if cfg.family == "moe":
        from repro.models import moe

        x = x + moe.moe_apply(params["moe"], L.norm(params["ln2"], x, cfg), cfg)
    else:
        x = x + L.mlp(params["mlp"], L.norm(params["ln2"], x, cfg), cfg)
    return x, new_cache


def init(key, cfg):
    ks = nn.split_keys(key, cfg.n_layers + 3)
    blocks = [init_block(ks[i], cfg) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    p = {
        "embed": nn.embed_init(ks[-3], cfg.vocab_padded, cfg.d_model, cfg.dtype),
        "blocks": stacked,
        "ln_f": L.norm_init(cfg),
        "lm_head": nn.dense_init(ks[-1], cfg.d_model, cfg.vocab_padded, cfg.dtype),
    }
    return p


def _scan_blocks(params, cfg, x, positions, caches=None, remat: bool = True,
                 spec: bool = False):
    """Scan over stacked layer params (and stacked caches on decode)."""

    def body(carry, layer):
        if caches is None:
            lp = layer
            y, _ = block(lp, cfg, carry, positions, None)
            return y, None
        lp, lc = layer
        y, nc = block(lp, cfg, carry, positions, lc, spec=spec)
        return y, nc

    from repro.models import probe_mode

    probing = probe_mode.enabled()
    fn = jax.checkpoint(body) if (remat and not probing) else body
    xs = params["blocks"] if caches is None else (params["blocks"], caches)
    x, new_caches = jax.lax.scan(fn, x, xs, unroll=True if probing else 1)
    return x, new_caches


def embed_inputs(params, cfg, tokens, embeds=None):
    """Token embedding; `embeds` (B, P, D) is the modality-frontend stub
    (precomputed patch/frame embeddings) prepended for vlm configs."""
    x = nn.embed(params["embed"], tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return nn.constrain_batch(x)


def forward(params, cfg, tokens, embeds=None, remat: bool = True):
    """Training/eval forward: logits (B, S_total, vocab_padded)."""
    x = embed_inputs(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, _ = _scan_blocks(params, cfg, x, positions, remat=remat)
    x = L.norm(params["ln_f"], x, cfg)
    return x  # pre-logits; loss computes the vocab projection chunked


def logits_fn(params, x):
    return nn.linear(params["lm_head"], x)


def make_cache(cfg, batch: int, max_seq: int, dtype=None, page=None,
               n_pages=None):
    """Decode cache with per-slot positions: every batch lane ("slot") tracks
    its own `pos` / `kpos`, so lanes can host independent requests at
    different decode depths (continuous batching).

    With ``page``/``n_pages`` set, K/V/kpos become shared physical page
    pools (``(L, n_pages, page, ...)``) addressed through a per-slot block
    table instead of per-slot ``max_seq`` stripes (serve paged pool)."""
    dtype = dtype or cfg.dtype
    if page is not None:
        geom = page_geometry(cfg, max_seq, page)
        kv = paging.make_attn_pool(cfg.n_layers, n_pages, geom["page"],
                                   cfg.n_kv_heads, cfg.head_dim, dtype)
        kv["pos"] = jnp.zeros((cfg.n_layers, batch), jnp.int32)
        kv.update(paging.make_tables(cfg.n_layers, batch, geom["n_bt"]))
        return kv
    kv = {
        "k": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.zeros((cfg.n_layers, batch), jnp.int32),
        "kpos": jnp.full((cfg.n_layers, batch, max_seq), 2**30, jnp.int32),
    }
    return kv


def page_geometry(cfg, max_seq: int, page: int) -> dict:
    """Paged-pool geometry: the full `max_seq` view is block-allocated."""
    return paging.geometry(max_seq, page)


def paged_insert(cfg, pool, stripe, slot, row, scatter_ids, bt_row, n_alloc):
    """Insert row `row` of a prefilled stripe cache into paged-pool slot
    `slot` whose pages are `scatter_ids`/`bt_row` (see serve.kv)."""
    return paging.insert_attn(pool, stripe, row, scatter_ids, bt_row,
                              n_alloc, slot)


def paged_release(cfg, pool, slot, page_ids):
    return paging.release_attn(pool, page_ids, slot)


def paged_map(cfg, pool, slot, bt_row, n_alloc, pos):
    """Map `slot` onto already-written pages (prefix sharing): block table
    and counters only — no K/V moves; the shared rows are live already."""
    return paging.map_attn(pool, bt_row, n_alloc, pos, slot)


def paged_copy_page(cfg, pool, dst, src, keep_rows):
    """Copy-on-write the divergent tail page (first `keep_rows` rows)."""
    return paging.copy_page(pool, dst, src, keep_rows)


def paged_sweep(cfg, pool, page_ids):
    """kpos-sentinel sweep of unreferenced pages (prefix-cache eviction)."""
    return paging.sweep_pages(pool, page_ids)


def cache_batch_axes(cfg, cache):
    """Axis of the request-slot (batch) dimension for every cache leaf —
    lets the serve slot pool insert/reset single slots generically.
    Paged-pool leaves carry no slot axis and map to None."""
    if paging.is_paged(cache):
        return paging.paged_axes(cache)
    return jax.tree.map(lambda _: 1, cache)


def cache_shard_roles(cfg, cache):
    """Sharding role per cache leaf (see distributed.sharding.cache_specs):
    paged pools shard their page axis, stripes their slot (batch) axis."""
    if paging.is_paged(cache):
        return paging.paged_roles(cache)
    return {"k": "kv", "v": "kv", "pos": "slot", "kpos": "slot"}


def prefill(params, cfg, tokens, cache, embeds=None, n_rows=None):
    """Fill the KV cache; returns (last-token pre-logits (B, D), cache).

    `n_rows` (B,) enables bucketed prefill: rows past a lane's true length
    are padding whose positions (and hence cached `kpos`) are the mask
    sentinel — never attended by real rows, overwritten in place as decode
    advances — so one jit serves every prompt length in the bucket."""
    x = embed_inputs(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    ar = jnp.arange(s, dtype=jnp.int32)
    if n_rows is None:
        positions = jnp.broadcast_to(ar, (b, s))
    else:
        positions = jnp.where(ar[None, :] < n_rows[:, None], ar[None, :],
                              paging.KPOS_SENTINEL)
    x, new_cache = _scan_blocks(params, cfg, x, positions, caches=cache)
    x = L.norm(params["ln_f"], x, cfg)
    if n_rows is None:
        return x[:, -1], new_cache
    last = jnp.take_along_axis(x, (n_rows - 1)[:, None, None], axis=1)[:, 0]
    # decode resumes at each lane's true length, not the padded bucket end
    new_cache = dict(new_cache, pos=jnp.broadcast_to(
        n_rows[None, :].astype(jnp.int32), new_cache["pos"].shape))
    return last, new_cache


def decode_step(params, cfg, tokens, cache):
    """One decode step. tokens (B, 1); returns (logits (B, vocab), cache)."""
    x = nn.embed(params["embed"], tokens)
    pos = cache["pos"][0]                       # (B,) per-slot positions
    positions = pos.astype(jnp.int32)[:, None]
    x, new_cache = _scan_blocks(params, cfg, x, positions, caches=cache)
    x = L.norm(params["ln_f"], x, cfg)
    return logits_fn(params, x[:, 0]), new_cache


# serve/spec: one parallel forward verifies all candidate rows (attention
# is the only stateful block, and its causal mask makes the multi-token
# write bitwise-equivalent to sequential steps on non-windowed caches)
SPEC_VERIFY = "parallel"


def cache_position(cfg, cache):
    """Per-slot cache write position (B,) int32 (serve/spec rollback)."""
    return cache["pos"][0]


def verify_step(params, cfg, tokens, cache):
    """Speculative verify: one forward over ``tokens (B, S)`` — the pending
    token plus S-1 draft candidates per slot — writing all S cache rows
    through the normal decode write path.  Returns (logits (B, S, vocab),
    cache, undo); rejected rows are swept back by `cache_rollback`."""
    b, s = tokens.shape
    x = nn.embed(params["embed"], tokens)
    pos = cache["pos"][0]
    positions = pos.astype(jnp.int32)[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    x, new_cache = _scan_blocks(params, cfg, x, positions, caches=cache,
                                spec=True)
    x = L.norm(params["ln_f"], x, cfg)
    return logits_fn(params, x), new_cache, None


def extend_step(params, cfg, tokens, cache):
    """Extension prefill: forward ``tokens (B, C)`` from each slot's current
    position, writing all C cache rows through the multi-token decode write
    path (the same parallel path verify_step uses, so every row is bitwise
    what sequential decode would have written).  Returns the pre-logits
    hidden states ``(B, C, D)`` — the caller projects only the rows it
    samples from — plus (cache, undo); chunked/suffix prefill rolls back
    co-resident lanes' junk rows with `cache_rollback` exactly like a
    rejected speculation."""
    b, s = tokens.shape
    x = nn.embed(params["embed"], tokens)
    pos = cache["pos"][0]
    positions = pos.astype(jnp.int32)[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    x, new_cache = _scan_blocks(params, cfg, x, positions, caches=cache,
                                spec=True)
    x = L.norm(params["ln_f"], x, cfg)
    return x, new_cache, None


def cache_rollback(cfg, cache, undo, pos0, keep, n_written):
    """Keep ``keep`` of the ``n_written`` speculative rows per slot: sweep
    the rejected suffix's kpos to the sentinel and rewind pos."""
    if paging.is_paged(cache):
        return paging.rollback_attn_paged(cache, pos0, keep, n_written,
                                          window=bool(cfg.window))
    return paging.rollback_attn_stripe(cache, pos0, keep, n_written,
                                       window=bool(cfg.window))


def hinm_plan(cfg) -> list[PruneSpec]:
    """Prunable projections per layer (paper: attention + FFN linears)."""
    specs = [
        PruneSpec("attn/wq", can_permute_rows=False),
        PruneSpec("attn/wk", can_permute_rows=False),
        PruneSpec(
            "attn/wv",
            row_blocks=cfg.n_kv_heads,
            consumers=("attn/wo:gqa",),
        ),
        PruneSpec("attn/wo", can_permute_rows=False),
    ]
    prefix = "moe" if cfg.family == "moe" else "mlp"
    if cfg.act == "swiglu":
        # gate/up rows are elementwise-coupled -> one shared OCP perm,
        # folded into wd's columns (free at runtime via its vec_idx).
        specs += [
            PruneSpec(f"{prefix}/wg", tied=(f"{prefix}/wu",), consumers=(f"{prefix}/wd",)),
            PruneSpec(f"{prefix}/wd", can_permute_rows=False),
        ]
    else:
        specs += [
            PruneSpec(f"{prefix}/wu", consumers=(f"{prefix}/wd",)),
            PruneSpec(f"{prefix}/wd", can_permute_rows=False),
        ]
    return specs
