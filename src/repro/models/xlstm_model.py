"""xLSTM-125m model: alternating (mLSTM, sLSTM) blocks, no separate FFN
(the blocks carry their own projections; cfg.d_ff == 0)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import module as nn
from repro.models import xlstm as X
from repro.models.module import PruneSpec


# fully recurrent: no paged KV (state is O(1)) and no bucketed prefill,
# hence nothing for the paged-attention kernel to resolve
BUCKETED_PREFILL = False
PAGED_ATTN_KERNEL = False


def _pattern(cfg):
    return cfg.block_pattern or ("mlstm", "slstm")


def init(key, cfg):
    pattern = _pattern(cfg)
    plen = len(pattern)
    if cfg.n_layers % plen:
        raise ValueError("xlstm n_layers must divide the block pattern")
    n_p = cfg.n_layers // plen
    ks = nn.split_keys(key, cfg.n_layers + 2)
    stacks = []
    for j, kind in enumerate(pattern):
        init_fn = X.mlstm_init if kind == "mlstm" else X.slstm_init
        layer_params = [init_fn(ks[p * plen + j], cfg) for p in range(n_p)]
        stacks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *layer_params))
    return {
        "embed": nn.embed_init(ks[-2], cfg.vocab_padded, cfg.d_model, cfg.dtype),
        "stacks": stacks,
        "ln_f": L.norm_init(cfg),
        "lm_head": nn.dense_init(ks[-1], cfg.d_model, cfg.vocab_padded, cfg.dtype),
    }


def _run(params, cfg, x, caches=None, remat: bool = True):
    pattern = _pattern(cfg)

    def period(carry, slices):
        x = nn.constrain_batch(carry)
        outs = []
        for j, kind in enumerate(pattern):
            fn = X.mlstm_block if kind == "mlstm" else X.slstm_block
            lc = None if caches is None else slices[2 * j + 1]
            x, nc = fn(slices[2 * j], cfg, x, lc)
            outs.append(nc)
        return x, tuple(outs)

    from repro.models import probe_mode

    probing = probe_mode.enabled()
    fn = jax.checkpoint(period) if (remat and not probing) else period
    xs = []
    for j in range(len(pattern)):
        xs += [params["stacks"][j], None if caches is None else caches[j]]
    x, new_caches = jax.lax.scan(fn, x, tuple(xs), unroll=True if probing else 1)
    return x, (new_caches if caches is not None else None)


def forward(params, cfg, tokens, embeds=None, remat: bool = True):
    x = nn.embed(params["embed"], tokens)
    x, _ = _run(params, cfg, x, remat=remat)
    return L.norm(params["ln_f"], x, cfg)


def logits_fn(params, x):
    return nn.linear(params["lm_head"], x)


def make_cache(cfg, batch: int, max_seq: int, dtype=None):
    del max_seq  # state is O(1) in sequence length
    pattern = _pattern(cfg)
    n_p = cfg.n_layers // len(pattern)
    d, h = cfg.d_model, cfg.n_heads
    dk = d // h
    caches = []
    for kind in pattern:
        if kind == "mlstm":
            caches.append({
                "c": jnp.zeros((n_p, batch, h, dk, dk), jnp.float32),
                "n": jnp.zeros((n_p, batch, h, dk), jnp.float32),
                "m": jnp.full((n_p, batch, h), -1e30, jnp.float32),
            })
        else:
            caches.append({
                "c": jnp.zeros((n_p, batch, d), jnp.float32),
                "n": jnp.ones((n_p, batch, d), jnp.float32),
                "h": jnp.zeros((n_p, batch, d), jnp.float32),
                "m": jnp.zeros((n_p, batch, d), jnp.float32),
            })
    return tuple(caches)


def cache_batch_axes(cfg, cache):
    """Slot (batch) axis per cache leaf; recurrent state is (n_p, B, ...)."""
    return jax.tree.map(lambda _: 1, cache)


def cache_shard_roles(cfg, cache):
    """Every leaf is O(1)-per-slot recurrent state (n_p, B, feat...): batch
    over dp, feature dim over 'model'. There is no paged layout to declare
    — the serve pool falls back to stripes (page_geometry is absent), and
    cache_specs must resolve this tree without assuming attention leaves."""
    return jax.tree.map(lambda _: "state", cache)


def prefill(params, cfg, tokens, cache, embeds=None, n_rows=None):
    if n_rows is not None:
        raise ValueError("xlstm prefill cannot be length-bucketed: recurrent"
                         " state would integrate the padded rows")
    x = nn.embed(params["embed"], tokens)
    x, new_cache = _run(params, cfg, x, caches=cache)
    return L.norm(params["ln_f"], x, cfg)[:, -1], new_cache


def decode_step(params, cfg, tokens, cache):
    x = nn.embed(params["embed"], tokens)
    x, new_cache = _run(params, cfg, x, caches=cache)
    x = L.norm(params["ln_f"], x, cfg)
    return logits_fn(params, x[:, 0]), new_cache


def hinm_plan(cfg):
    pattern = _pattern(cfg)
    return {j: X.xlstm_plan_specs(kind) for j, kind in enumerate(pattern)}
