"""Family dispatch: one uniform interface over all assigned architectures.

  init(key, cfg)                          -> params
  forward(params, cfg, batch...)          -> pre-logits (B, S, D)
  logits_fn(params, x)                    -> vocab projection
  make_cache(cfg, batch, max_seq)         -> decode cache pytree
  cache_batch_axes(cfg, cache)            -> slot axis per cache leaf
  cache_shard_roles(cfg, cache)           -> sharding role per cache leaf
  prefill / decode_step                   -> serving
  hinm_plan(cfg)                          -> prune specs (see repro.perm)
  perm_graph(cfg)                         -> compiled ModelPermGraph
"""
from __future__ import annotations

from repro.models import encdec, hybrid, transformer, xlstm_model

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "hybrid": hybrid,
    "ssm": xlstm_model,
    "encdec": encdec,
}


def model_for(cfg):
    return _FAMILY[cfg.family]


def init(key, cfg):
    return model_for(cfg).init(key, cfg)


def forward(params, cfg, tokens, embeds=None, remat: bool = True):
    return model_for(cfg).forward(params, cfg, tokens, embeds=embeds, remat=remat)


def logits_fn(params, cfg, x):
    return model_for(cfg).logits_fn(params, x)


def make_cache(cfg, batch: int, max_seq: int, dtype=None, **kw):
    return model_for(cfg).make_cache(cfg, batch, max_seq, dtype=dtype, **kw)


def cache_batch_axes(cfg, cache):
    """Pytree (matching `cache`) of the request-slot axis per leaf.

    The serve slot pool uses this to insert a freshly prefilled batch-1
    cache into one slot of the pooled cache — and to reset a slot on
    request completion — with a single `dynamic_update_slice_in_dim` per
    leaf, without knowing family cache internals."""
    return model_for(cfg).cache_batch_axes(cfg, cache)


def cache_shard_roles(cfg, cache):
    """Pytree (matching `cache`) of sharding roles per leaf — the family's
    declaration of its cache layout to `distributed.sharding.cache_specs`:
    "page" (shared paged-pool leaf, page axis sharded), "kv" (stripe K/V),
    "slot" (per-slot bookkeeping), "enc" (cached encoder leaves), "state"
    (recurrent state)."""
    return model_for(cfg).cache_shard_roles(cfg, cache)


def prefill(params, cfg, tokens, cache, embeds=None, n_rows=None):
    """Fill the decode cache. `n_rows` (B,) enables bucketed prefill on
    pure-attention families (see `supports_bucketed_prefill`): rows past a
    lane's true length are sentinel-masked padding."""
    return model_for(cfg).prefill(params, cfg, tokens, cache, embeds=embeds,
                                  n_rows=n_rows)


def supports_bucketed_prefill(cfg) -> bool:
    """Whether prompts can be padded to length buckets at prefill: true for
    pure-attention stacks (masked pads are exact), false when recurrent
    blocks would integrate the padding into their state."""
    return getattr(model_for(cfg), "BUCKETED_PREFILL", False)


def page_geometry(cfg, max_seq: int, page: int):
    """dict(view, page, n_bt) for a paged decode cache, or None for
    families whose decode state cannot be paged (pure recurrent)."""
    fn = getattr(model_for(cfg), "page_geometry", None)
    return None if fn is None else fn(cfg, max_seq, page)


def paged_insert(cfg, pool, stripe, slot, row, scatter_ids, bt_row, n_alloc):
    """Insert row `row` of a prefilled stripe cache into paged-pool slot
    `slot`: scatter K/V/kpos pieces to physical pages `scatter_ids`,
    install block-table row `bt_row`, copy the striped leaves."""
    return model_for(cfg).paged_insert(cfg, pool, stripe, slot, row,
                                       scatter_ids, bt_row, n_alloc)


def paged_release(cfg, pool, slot, page_ids):
    """Release a paged-pool slot: freed pages' kpos rows return to the
    sentinel and the slot's striped leaves go pristine.  With refcounted
    sharing the caller (serve.kv) passes only the pages whose LAST
    reference dropped — sweeping a still-shared page would erase rows a
    co-owning slot is attending to."""
    return model_for(cfg).paged_release(cfg, pool, slot, page_ids)


def supports_prefix_share(cfg) -> bool:
    """Whether identical token prefixes cache bitwise-identical K/V rows
    that other slots may map refcount-shared (serve/prefix): true for
    pure-attention stacks whose rows are per-(token, position) projections,
    false for recurrent/hybrid state (prefix state is not page-local) and
    for windowed rings (a wrapped ring reuses page rows in place)."""
    return getattr(model_for(cfg), "PREFIX_SHARE", False) and not cfg.window


def paged_map(cfg, pool, slot, bt_row, n_alloc, pos):
    """Map slot `slot` onto already-written physical pages (prefix
    sharing): installs `bt_row`/`n_alloc` and sets pos — no K/V moves."""
    return model_for(cfg).paged_map(cfg, pool, slot, bt_row, n_alloc, pos)


def paged_copy_page(cfg, pool, dst, src, keep_rows):
    """Copy-on-write a divergent tail page: K/V bytes of `src` into `dst`,
    kpos rows past `keep_rows` landing as the sentinel."""
    return model_for(cfg).paged_copy_page(cfg, pool, dst, src, keep_rows)


def paged_sweep(cfg, pool, page_ids):
    """Sweep unreferenced pages' kpos rows to the sentinel without touching
    any slot's table (prefix-cache eviction path)."""
    return model_for(cfg).paged_sweep(cfg, pool, page_ids)


def decode_step(params, cfg, tokens, cache):
    return model_for(cfg).decode_step(params, cfg, tokens, cache)


def supports_spec_decode(cfg) -> bool:
    """Whether the family implements the speculative verify/rollback pair
    (serve/spec).  Parallel verifiers (pure-attention stacks) are excluded
    on windowed configs — a wrapped multi-token write would clobber live
    ring rows; sequential verifiers (hybrid) snapshot-and-restore instead.
    Pure-recurrent families (xlstm) have no verify path."""
    mode = getattr(model_for(cfg), "SPEC_VERIFY", None)
    if mode is None:
        return False
    return mode == "sequential" or not cfg.window


def verify_step(params, cfg, tokens, cache):
    """Speculative verify: forward `tokens (B, S)` (pending token + S-1
    draft candidates per slot), writing all S cache rows.  Returns
    (logits (B, S, vocab_padded), cache, undo)."""
    return model_for(cfg).verify_step(params, cfg, tokens, cache)


def extend_step(params, cfg, tokens, cache):
    """Extension prefill (chunked admission / shared-prefix suffix): write
    ``tokens (B, C)`` from each slot's position through the multi-token
    decode path and return (pre-logits hidden (B, C, D), cache, undo).
    Verify's twin without the full-width vocab projection — the caller
    projects only the final row it samples the first token from."""
    return model_for(cfg).extend_step(params, cfg, tokens, cache)


def cache_rollback(cfg, cache, undo, pos0, keep, n_written):
    """Commit/rollback after a verify: keep `keep (B,)` of the `n_written`
    speculative rows per slot (sweep or snapshot-restore the rejected
    suffix) and rewind the position counters to `pos0 + keep`."""
    return model_for(cfg).cache_rollback(cfg, cache, undo, pos0, keep,
                                         n_written)


def cache_position(cfg, cache):
    """Per-slot cache write position (B,) int32."""
    return model_for(cfg).cache_position(cfg, cache)


def supports_paged_attn_kernel(cfg) -> bool:
    """Whether the family's paged decode cache can be resolved by the
    Pallas paged-attention kernel (kernels/paged_attn): true for every
    family whose pool is the shared (n_pages, page, KV, hd) layout —
    windowed rings included, the window folds into the kernel's mask —
    false for pure-recurrent families that never page at all."""
    return getattr(model_for(cfg), "PAGED_ATTN_KERNEL", False)


def pack_params(cfg, params):
    """Pack every planned projection's dense weight into PackedHiNM —
    the serve-time packing hook (one-time, at engine construction), after
    which ``hinm_spmm`` is the q/k/v/o and MLP projection path for
    prefill, decode and spec-verify via ``nn.linear``'s dispatch.

    Already-packed leaves pass through untouched.  A weight that is not
    already HiNM-sparse is magnitude-pruned by the packing itself; that
    is lossless only when the weight's sparsity pattern matches the
    default ascending-column grouping (packing here applies no gyro/ICP
    permutation, so re-packing a masked-dense weight from a *permuted*
    ``prune_model`` packing regroups columns and is lossy — keep the
    original PackedHiNM leaves for those; ``unpack_params`` is the exact
    direction)."""
    import jax as _jax

    from repro.core import packing
    from repro.core.types import PackedHiNM
    from repro.models import module as nn
    from repro.perm.graph import get_container, set_container

    for key, sel, spec in perm_graph(cfg).instances():
        container = get_container(params, key, sel)
        node = dict(nn.get_path(container, spec.path))
        w = node["w"]
        if isinstance(w, PackedHiNM):
            continue
        fn = lambda w2: packing.pack(w2.T, cfg.hinm)  # stored (n_in, n_out)
        for _ in range(w.ndim - 2):                   # layer / expert stacks
            fn = _jax.vmap(fn)
        node["w"] = fn(w)
        container = nn.set_path(container, spec.path, node)
        params = set_container(params, key, sel, container)
    return params


def unpack_params(cfg, params):
    """Dense fallback for the packed serving mode: every planned
    projection's PackedHiNM weight back to its masked-dense (n_in, n_out)
    stored form, so ``nn.linear`` runs plain matmuls on the same numbers."""
    import jax as _jax

    from repro.core import packing
    from repro.core.types import PackedHiNM
    from repro.models import module as nn
    from repro.perm.graph import get_container, set_container

    for key, sel, spec in perm_graph(cfg).instances():
        container = get_container(params, key, sel)
        node = dict(nn.get_path(container, spec.path))
        w = node["w"]
        if not isinstance(w, PackedHiNM):
            continue
        fn = lambda p: packing.unpack(p).T
        for _ in range(w.vals.ndim - 3):              # layer / expert stacks
            fn = _jax.vmap(fn)
        node["w"] = fn(w)
        container = nn.set_path(container, spec.path, node)
        params = set_container(params, key, sel, container)
    return params


def hinm_plan(cfg):
    return model_for(cfg).hinm_plan(cfg)


def perm_graph(cfg):
    """Compile this model's hinm_plan into a validated ModelPermGraph."""
    from repro.perm.graph import compile_model_graph

    return compile_model_graph(cfg)
