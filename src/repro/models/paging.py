"""Shared helpers for the paged KV pool (serve slot pool, PR 3).

A paged decode cache replaces each per-slot ``(B, S, ...)`` KV stripe
with one shared physical page buffer per leaf, ``(n_pages, page, ...)``,
plus a per-slot block table ``bt (B, n_bt)`` of physical page ids and a
per-slot allocated-page count ``alloc (B,)``.  The block table is the
runtime analogue of the HiNM kernel's ``vec_idx``: attention resolves a
slot's logical rows through ``bt`` with a sublane gather (``pool[bt]``)
into a contiguous lane view, exactly like ``kernels/hinm_spmm`` gathers
kept input channels — a permuted table costs the same as an identity one.

Two physical pages are reserved:

  ``SCRATCH_PAGE`` (0)  — write sink.  Idle lanes keep stepping inside a
      decode chunk (fixed-shape batch) and their row writes must land
      somewhere; any write whose logical page is outside the slot's
      allocation is redirected here.  No block table ever references it,
      so scratch content is unreachable by attention.
  ``SENTINEL_PAGE`` (1) — read-only masked page.  Every unassigned block
      table entry points here; its ``kpos`` rows stay at ``KPOS_SENTINEL``
      forever (writes can't reach it — they go to an allocated page or to
      scratch), so gathered views mask the unallocated tail to an exact
      zero contribution in the online softmax.

Freed pages keep stale K/V but their ``kpos`` rows are reset to the
sentinel on release, so a page recycled to a new slot can never leak rows
into a view until the new owner writes them.  With prefix sharing
(serve/prefix) a physical page can appear in several block tables at
once; ownership of the *kpos sweep* then moves to the refcount layer
(serve/kv): only a page whose last reference drops is swept — sweeping a
still-shared page would erase rows a co-owner is attending to.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

KPOS_SENTINEL = 2**30
SCRATCH_PAGE = 0
SENTINEL_PAGE = 1
N_RESERVED = 2


def shard_geometry(n_alloc: int, n_shards: int = 1) -> dict:
    """Total physical page count for an ``n_shards``-way sharded pool.

    The page axis is the sharded axis of every pool leaf, so the TOTAL page
    count — allocatable pages plus the two reserved pages (scratch and
    sentinel are pool-global: they live on the shard that owns ids 0/1 and
    are reached through the same SPMD gather as any other page) — must
    divide the mesh. The count is rounded UP so provisioning never shrinks;
    the padding pages join the free list as ordinary allocatable pages.

    Returns dict(n_pages, n_alloc, pages_per_shard).
    """
    n_shards = max(1, int(n_shards))
    total = N_RESERVED + max(1, int(n_alloc))
    total = -(-total // n_shards) * n_shards
    return {"n_pages": total, "n_alloc": total - N_RESERVED,
            "pages_per_shard": total // n_shards}


def geometry(view_len: int, page: int) -> dict:
    """Resolve page geometry for a logical view of ``view_len`` rows.

    ``page`` is clamped to the view and halved until it divides it, so any
    requested size yields a valid layout. Returns dict(view, page, n_bt).
    """
    page = max(1, min(page, view_len))
    while view_len % page:
        page //= 2
    return {"view": view_len, "page": page, "n_bt": view_len // page}


def make_attn_pool(n_stack: int, n_pages: int, page: int, n_kv_heads: int,
                   head_dim: int, dtype) -> dict:
    """Physical page buffers for one attention stack: k/v/kpos leaves with
    the ``(B, S)`` stripe axes replaced by ``(n_pages, page)``."""
    return {
        "k": jnp.zeros((n_stack, n_pages, page, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((n_stack, n_pages, page, n_kv_heads, head_dim), dtype),
        "kpos": jnp.full((n_stack, n_pages, page), KPOS_SENTINEL, jnp.int32),
    }


def make_tables(n_stack: int, batch: int, n_bt: int) -> dict:
    """Pristine per-slot block table + allocation count, replicated over the
    stack axis so they scan alongside the per-layer pool leaves."""
    return {
        "bt": jnp.full((n_stack, batch, n_bt), SENTINEL_PAGE, jnp.int32),
        "alloc": jnp.zeros((n_stack, batch), jnp.int32),
    }


def gather_view(pool: jax.Array, bt: jax.Array) -> jax.Array:
    """Resolve a slot's logical view through its block table: ``pool``
    ``(n_pages, page, ...)`` gathered by ``bt (B, n_bt)`` into a contiguous
    ``(B, n_bt * page, ...)`` lane view — the jnp reference realisation of
    the block-table walk (``kernels/paged_attn`` streams the same pages
    in-grid without materialising this copy)."""
    b, n_bt = bt.shape
    page = pool.shape[1]
    return jnp.take(pool, bt, axis=0).reshape(
        (b, n_bt * page) + pool.shape[2:])


def is_paged(cache) -> bool:
    """True for a (per-layer slice of a) paged attention cache dict."""
    return isinstance(cache, dict) and "bt" in cache


# slot axis of the leaves that stay per-slot inside a paged attn cache;
# pool leaves (k/v/kpos) carry no slot axis and map to None
STRIPED_AXES = {"pos": 1, "bt": 1, "alloc": 1}


def paged_axes(cache: dict) -> dict:
    """Slot-axis map for one paged attn cache dict (see cache_batch_axes)."""
    return {k: STRIPED_AXES.get(k) for k in cache}


# sharding roles per paged-pool leaf (see distributed.sharding.cache_specs):
# pool leaves shard their page axis, per-slot leaves their slot (batch) axis
PAGED_ROLES = {"k": "page", "v": "page", "kpos": "page",
               "pos": "slot", "bt": "slot", "alloc": "slot"}


def paged_roles(cache: dict) -> dict:
    """Sharding-role map for one paged attn cache dict."""
    return {k: PAGED_ROLES.get(k, "slot") for k in cache}


def scatter_rows(pool: jax.Array, stripe: jax.Array, row, scatter_ids) -> jax.Array:
    """Copy slot-row ``row`` of a striped leaf into physical pages.

    pool ``(n_stack, n_pages, page, ...)``; stripe ``(n_stack, B, S, ...)``
    with ``S >= n_bt * page``; ``scatter_ids (n_bt,)`` int32 physical ids,
    entries past the allocation pointing at SCRATCH_PAGE (duplicate scratch
    writes race benignly — scratch is unreachable by reads).
    """
    page = pool.shape[2]
    n_bt = scatter_ids.shape[0]
    one = jax.lax.dynamic_slice_in_dim(stripe, row, 1, axis=1)[:, 0]
    pieces = one[:, : n_bt * page].reshape(
        (one.shape[0], n_bt, page) + one.shape[2:]).astype(pool.dtype)
    return pool.at[:, scatter_ids].set(pieces)


def insert_attn(pool: dict, stripe: dict, row, scatter_ids, bt_row, n_alloc,
                slot) -> dict:
    """Insert a prefilled stripe-cache row into a paged attention stack:
    scatter k/v/kpos pieces to their physical pages, copy the per-slot
    ``pos`` counter, and install the block table row."""
    out = dict(pool)
    for name in ("k", "v", "kpos"):
        out[name] = scatter_rows(pool[name], stripe[name], row, scatter_ids)
    one = jax.lax.dynamic_slice_in_dim(stripe["pos"], row, 1, axis=1)
    out["pos"] = jax.lax.dynamic_update_slice_in_dim(
        pool["pos"], one, slot, axis=1)
    n_stack, _, n_bt = pool["bt"].shape
    out["bt"] = jax.lax.dynamic_update_slice_in_dim(
        pool["bt"], jnp.broadcast_to(bt_row, (n_stack, 1, n_bt)), slot, axis=1)
    out["alloc"] = jax.lax.dynamic_update_slice_in_dim(
        pool["alloc"], jnp.broadcast_to(n_alloc, (n_stack, 1)).astype(jnp.int32),
        slot, axis=1)
    return out


def release_attn(pool: dict, page_ids, slot) -> dict:
    """Release a slot from a paged attention stack: freed pages' kpos rows
    return to the sentinel (stale K/V becomes unreachable the moment the
    page is recycled), and the slot's table/counters go pristine.
    ``page_ids (n_bt,)`` is padded with SCRATCH_PAGE (resetting scratch
    kpos is harmless — it is never read)."""
    out = dict(pool)
    out["kpos"] = pool["kpos"].at[:, page_ids].set(KPOS_SENTINEL)
    n_stack, _, n_bt = pool["bt"].shape
    out["pos"] = jax.lax.dynamic_update_slice_in_dim(
        pool["pos"], jnp.zeros((n_stack, 1), jnp.int32), slot, axis=1)
    out["bt"] = jax.lax.dynamic_update_slice_in_dim(
        pool["bt"], jnp.full((n_stack, 1, n_bt), SENTINEL_PAGE, jnp.int32),
        slot, axis=1)
    out["alloc"] = jax.lax.dynamic_update_slice_in_dim(
        pool["alloc"], jnp.zeros((n_stack, 1), jnp.int32), slot, axis=1)
    return out


def map_attn(pool: dict, bt_row, n_alloc, pos, slot) -> dict:
    """Map a slot onto already-written physical pages without any scatter:
    install the block-table row / allocation count and set ``pos`` to the
    rows the mapped prefix already holds (prefix sharing: the shared pages
    carry another owner's K/V rows, bitwise-identical for an identical
    token prefix at identical positions).  The suffix is written later by
    extension prefill through the normal multi-token decode path."""
    out = dict(pool)
    n_stack, _, n_bt = pool["bt"].shape
    out["pos"] = jax.lax.dynamic_update_slice_in_dim(
        pool["pos"], jnp.broadcast_to(pos, (n_stack, 1)).astype(jnp.int32),
        slot, axis=1)
    out["bt"] = jax.lax.dynamic_update_slice_in_dim(
        pool["bt"], jnp.broadcast_to(bt_row, (n_stack, 1, n_bt)), slot, axis=1)
    out["alloc"] = jax.lax.dynamic_update_slice_in_dim(
        pool["alloc"], jnp.broadcast_to(n_alloc, (n_stack, 1)).astype(jnp.int32),
        slot, axis=1)
    return out


def copy_page(pool: dict, dst, src, keep_rows) -> dict:
    """Copy-on-write a divergent tail page: physical page ``src``'s k/v
    bytes are copied to ``dst``, and only the first ``keep_rows`` kpos rows
    come along — the donor's rows past the divergence point must not leak
    into the new owner's view, so they land as the sentinel (exactly like
    unwritten rows; extension prefill overwrites them in place)."""
    out = dict(pool)
    page = pool["k"].shape[2]
    for name in ("k", "v"):
        rows = jax.lax.dynamic_index_in_dim(pool[name], src, 1, keepdims=False)
        out[name] = jax.lax.dynamic_update_index_in_dim(
            pool[name], rows, dst, 1)
    shared = jnp.arange(page, dtype=jnp.int32) < keep_rows
    kp = jax.lax.dynamic_index_in_dim(pool["kpos"], src, 1, keepdims=False)
    kp = jnp.where(shared[None, :], kp, KPOS_SENTINEL)
    out["kpos"] = jax.lax.dynamic_update_index_in_dim(pool["kpos"], kp, dst, 1)
    return out


def sweep_pages(pool: dict, page_ids) -> dict:
    """Reset ``page_ids``' kpos rows to the sentinel without touching any
    slot's table (a prefix-cache eviction frees pages that no block table
    references; padding with SCRATCH_PAGE is harmless, it is never read)."""
    out = dict(pool)
    out["kpos"] = pool["kpos"].at[:, page_ids].set(KPOS_SENTINEL)
    return out


# ---------------------------------------------------------------------------
# speculative decoding: multi-token row addressing, commit/rollback
# ---------------------------------------------------------------------------
#
# A verify step writes up to `n` speculative rows per slot starting at the
# slot's current position (serve/spec).  Rows land through the exact same
# addressing as single-token decode: logical page = vpos // page resolved
# through the block table, writes outside the allocation redirected to the
# scratch page.  Rollback keeps the accepted prefix and sweeps the rejected
# suffix's `kpos` back to the sentinel — the K/V bytes stay (unreachable:
# every future attend masks them exactly like an unwritten row) and the
# next verify overwrites them in place, so no page ever moves: the free
# list and pool bytes are untouched by accept/reject churn.


def spec_row_locations(bt: jax.Array, alloc: jax.Array, pos0: jax.Array,
                       n: int, page: int, window: bool):
    """Physical (page, offset) of the `n` speculative rows written per slot
    from ``pos0``.  bt (B, n_bt), alloc (B,), pos0 (B,).  Returns
    (phys (B, n), off (B, n), valid (B, n)) — ``valid`` False where the row
    falls outside the slot's allocation (those writes went to scratch)."""
    n_bt = bt.shape[1]
    view = n_bt * page
    ar = jnp.arange(n, dtype=jnp.int32)
    vpos = pos0[:, None] + ar[None, :]
    if window:
        vpos = jax.lax.rem(vpos, view)
    logical = jnp.clip(vpos // page, 0, n_bt - 1)
    off = jax.lax.rem(vpos, page)
    valid = (vpos // page) < alloc[:, None]
    phys = jnp.take_along_axis(bt, logical, axis=1)
    return phys, off, valid


def rollback_attn_paged(pool: dict, pos0: jax.Array, keep: jax.Array, n: int,
                        window: bool) -> dict:
    """Keep ``keep`` of the ``n`` speculative rows written from ``pos0`` in
    a paged attention stack: the rejected suffix's kpos rows return to the
    sentinel (k/v bytes stay — masked exactly like unwritten rows) and the
    position counter rewinds to ``pos0 + keep``.  Sweeps of kept or
    out-of-allocation rows are redirected to the scratch page (no-ops)."""
    page = pool["k"].shape[2]
    phys, off, valid = spec_row_locations(
        pool["bt"][0], pool["alloc"][0], pos0, n, page, window)
    drop = jnp.arange(n, dtype=jnp.int32)[None, :] >= keep[:, None]
    phys_sw = jnp.where(valid & drop, phys, SCRATCH_PAGE)
    out = dict(pool)
    out["kpos"] = pool["kpos"].at[:, phys_sw, off].set(KPOS_SENTINEL)
    out["pos"] = jnp.broadcast_to(
        (pos0 + keep).astype(jnp.int32)[None, :], pool["pos"].shape)
    return out


def rollback_attn_stripe(cache: dict, pos0: jax.Array, keep: jax.Array, n: int,
                         window: bool) -> dict:
    """Stripe-layout twin of ``rollback_attn_paged``: rejected rows' kpos
    back to the sentinel at their ring/stripe slots, pos rewound.  Writes
    past the stripe end (over-reservation rows that a scatter already
    dropped) are dropped again here by the same out-of-bounds rule."""
    smax = cache["k"].shape[2]
    b = pos0.shape[0]
    ar = jnp.arange(n, dtype=jnp.int32)
    idx = pos0[:, None] + ar[None, :]
    if window:
        idx = jax.lax.rem(idx, smax)
    drop = ar[None, :] >= keep[:, None]
    bidx = jnp.arange(b)[:, None]
    cur = cache["kpos"][:, bidx, idx]                       # (L, B, n)
    out = dict(cache)
    out["kpos"] = cache["kpos"].at[:, bidx, idx].set(
        jnp.where(drop[None], KPOS_SENTINEL, cur))
    out["pos"] = jnp.broadcast_to(
        (pos0 + keep).astype(jnp.int32)[None, :], cache["pos"].shape)
    return out


def _row_loc_at(cache: dict, pos: jax.Array, window: bool):
    """Per-slot index pair of the cache row a single-token decode step at
    position ``pos`` writes: (page, offset) for a paged stack, (lane, slot)
    for a stripe (clamped at the stripe end, matching the write's clamp)."""
    if is_paged(cache):
        page = cache["k"].shape[2]
        phys, off, valid = spec_row_locations(
            cache["bt"][0], cache["alloc"][0], pos, 1, page, window)
        return jnp.where(valid, phys, SCRATCH_PAGE)[:, 0], off[:, 0]
    smax = cache["k"].shape[2]
    idx = jax.lax.rem(pos, smax) if window else jnp.clip(pos, 0, smax - 1)
    return jnp.arange(pos.shape[0]), idx


def snapshot_attn_row(cache: dict, window: bool) -> dict:
    """Copy the row the next decode step will overwrite (sequential spec
    verify, see hybrid.verify_step): (L, B, ...) per k/v/kpos leaf."""
    i, j = _row_loc_at(cache, cache["pos"][0], window)
    return {name: cache[name][:, i, j] for name in ("k", "v", "kpos")}


def restore_attn_rows(cache: dict, snaps: dict, pos0: jax.Array,
                      keep: jax.Array, n: int, window: bool) -> dict:
    """Undo the rejected suffix of ``n`` sequential decode writes: rows
    ``i >= keep`` return to their pre-verify snapshot (``snaps`` leaves are
    step-stacked ``(n, L, B, ...)``), pos rewinds to ``pos0 + keep``.
    Restores run in reverse step order so a row written twice (stripe-end
    clamping) recovers the content the FIRST write clobbered."""

    def body(j, leaves):
        i = n - 1 - j
        ii, jj = _row_loc_at(cache, pos0 + i, window)
        drop = i >= keep                                     # (B,)
        out = {}
        for nm in ("k", "v", "kpos"):
            cur = leaves[nm][:, ii, jj]                      # (L, B, ...)
            snap = jax.lax.dynamic_index_in_dim(snaps[nm], i, 0, False)
            sel = jnp.where(
                drop.reshape((1, -1) + (1,) * (cur.ndim - 2)), snap, cur)
            out[nm] = leaves[nm].at[:, ii, jj].set(sel)
        return out

    leaves = {nm: cache[nm] for nm in ("k", "v", "kpos")}
    leaves = jax.lax.fori_loop(0, n, body, leaves)
    out = dict(cache, **leaves)
    out["pos"] = jnp.broadcast_to(
        (pos0 + keep).astype(jnp.int32)[None, :], cache["pos"].shape)
    return out


def select_state(snaps: jax.Array, final: jax.Array, keep: jax.Array) -> jax.Array:
    """Rewind a per-slot recurrent state to ``keep`` accepted tokens.
    ``snaps`` (n, L, B, ...) holds the state before each of the n verify
    steps (snap[0] = pre-verify), ``final`` (L, B, ...) the state after all
    n; returns the state after exactly ``keep[b]`` tokens per slot."""
    states = jnp.concatenate([snaps, final[None]], axis=0)   # (n+1, L, B, ...)
    states = jnp.moveaxis(states, 2, 0)                      # (B, n+1, L, ...)
    idx = keep.reshape((-1,) + (1,) * (states.ndim - 1)).astype(jnp.int32)
    out = jnp.take_along_axis(states, idx, axis=1)[:, 0]
    return jnp.moveaxis(out, 0, 1)


def copy_slot_row(dst: jax.Array, src: jax.Array, slot, row, axis: int) -> jax.Array:
    """Copy slot-row ``row`` of striped leaf ``src`` into row ``slot`` of
    ``dst`` along ``axis`` (the generic non-paged-leaf insert)."""
    one = jax.lax.dynamic_slice_in_dim(src, row, 1, axis=axis)
    return jax.lax.dynamic_update_slice_in_dim(
        dst, one.astype(dst.dtype), slot, axis=axis)


def reset_slot_row(leaf: jax.Array, slot, axis: int, fill=0) -> jax.Array:
    """Reset one slot row of a striped (non-paged) leaf to ``fill``."""
    shape = leaf.shape[:axis] + (1,) + leaf.shape[axis + 1:]
    return jax.lax.dynamic_update_slice_in_dim(
        leaf, jnp.full(shape, fill, leaf.dtype), slot, axis=axis)
