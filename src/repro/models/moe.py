"""Top-k MoE FFN with capacity-based sort-free dispatch.

Dispatch is the scatter/gather formulation (static shapes, EP/TP-shardable):
tokens are routed to a fixed-capacity (E, C, D) buffer via one-hot position
assignment computed with cumsum over expert one-hots — no (B,S,E,C) GShard
dispatch tensor is ever materialised. Tokens overflowing an expert's
capacity are dropped (standard Switch behaviour); capacity_factor controls
the drop rate.

Expert weights are stacked (E, d_in, d_out). Sharding: experts go
expert-parallel over 'model' when E divides the axis, otherwise
tensor-parallel inside each expert over d_ff (see distributed/sharding.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import module as nn


def moe_init(key, cfg):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = nn.split_keys(key, 4)

    def stack_init(k, n_in, n_out):
        keys = nn.split_keys(k, e)
        return {"w": jnp.stack([nn.uniform_init(kk, n_in, n_out, cfg.dtype) for kk in keys])}

    return {
        "router": nn.dense_init(ks[0], d, e, jnp.float32),
        "wg": stack_init(ks[1], d, f),
        "wu": stack_init(ks[2], d, f),
        "wd": stack_init(ks[3], f, d),
    }


def _expert_linear(p, x):
    """x: (E, C, d_in) @ w: (E, d_in, d_out) -> (E, C, d_out)."""
    from repro.core.types import PackedHiNM
    from repro.kernels import ops as kops

    w = p["w"]
    if isinstance(w, PackedHiNM):
        # per-expert packed weights (array fields carry a leading E axis);
        # the vmap multiplies the tile-chunk transient by E, so shrink the
        # per-call chunk budget accordingly
        e = x.shape[0]
        cb = max(1 << 20, 256 * 1024 * 1024 // (8 * e))
        return jax.vmap(lambda pe, xe: kops.hinm_matmul(xe, pe, chunk_bytes=cb))(w, x)
    return jnp.einsum("ecd,edf->ecf", x, w.astype(x.dtype))


def moe_apply(params, x: jax.Array, cfg, capacity_factor: float = 1.25) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    nt = b * s
    xf = x.reshape(nt, d)

    logits = nn.linear(params["router"], xf.astype(jnp.float32))     # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                              # (N, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    cap = int(nt * k * capacity_factor / e)
    cap = max(8, ((cap + 7) // 8) * 8)

    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)                 # (N, k, E)
    flat = onehot.reshape(nt * k, e)
    pos_in_e = jnp.cumsum(flat, axis=0) - flat                        # (N*k, E)
    pos = (pos_in_e * flat).sum(-1).reshape(nt, k)                    # (N, k)
    keep = pos < cap
    eid = topi

    # scatter tokens into (E, C, D); token-capacity dim stays data-parallel
    buf = jnp.zeros((e, cap, d), x.dtype)
    tok_ids = jnp.broadcast_to(jnp.arange(nt)[:, None], (nt, k))
    flat_eid = jnp.where(keep, eid, 0).reshape(-1)
    flat_pos = jnp.where(keep, pos, cap - 1).reshape(-1)  # dropped -> overwritten slot
    flat_keep = keep.reshape(-1)
    src = jnp.where(flat_keep[:, None], xf[tok_ids.reshape(-1)], 0).astype(x.dtype)
    buf = buf.at[flat_eid, flat_pos].add(src * flat_keep[:, None].astype(x.dtype))
    buf = nn.constrain(buf, (None, "dp", None))

    # expert FFN (swiglu); hidden stays (capacity x dp, d_ff x tp)
    gate = jax.nn.silu(_expert_linear(params["wg"], buf).astype(jnp.float32))
    up = _expert_linear(params["wu"], buf).astype(jnp.float32)
    hidden = nn.constrain((gate * up).astype(x.dtype), (None, "dp", "tp"))
    out_buf = _expert_linear(params["wd"], hidden)          # (E, C, D)
    out_buf = nn.constrain(out_buf, (None, "dp", None))

    # gather back with routing weights
    gathered = out_buf[flat_eid, flat_pos]                             # (N*k, D)
    gathered = gathered * (topv.reshape(-1, 1) * flat_keep[:, None]).astype(gathered.dtype)
    y = gathered.reshape(nt, k, d).sum(axis=1)
    return y.reshape(b, s, d)


def aux_load_balance_loss(params, x: jax.Array, cfg) -> jax.Array:
    """Switch-style load-balance auxiliary loss (mean fraction * mean prob)."""
    b, s, d = x.shape
    logits = nn.linear(params["router"], x.reshape(-1, d).astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32), axis=0)
    return cfg.n_experts * jnp.sum(frac * probs.mean(axis=0))
