"""RecurrentGemma-style hybrid stack: pattern (rec, rec, attn) per period,
each layer = temporal mixer + MLP (Griffin residual-block structure).

Layers are grouped by pattern position into scan stacks (n_layers need not
divide the pattern length — leftover layers run as a partial period), so
HLO size stays depth-independent while allowing heterogeneous blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import module as nn
from repro.models import paging
from repro.models import rglru
from repro.models.module import PruneSpec

# recurrent blocks integrate padded rows into their state — prompt-length
# bucketing would corrupt the rglru/conv carries, so admission stays exact
BUCKETED_PREFILL = False
# attention layers page their windowed ring into the shared pool; the
# paged-attention kernel folds the sliding window into its kpos mask, so
# the ring resolves through the same block-table walk as a full cache
PAGED_ATTN_KERNEL = True


def _layer_kinds(cfg) -> list[str]:
    p = cfg.block_pattern or ("rec", "rec", "attn")
    return [p[i % len(p)] for i in range(cfg.n_layers)]


def init_layer(key, cfg, kind: str):
    ks = nn.split_keys(key, 2)
    if kind == "attn":
        mixer = {"ln": L.norm_init(cfg), "attn": L.attention_init(ks[0], cfg)}
    else:
        mixer = rglru.rglru_block_init(ks[0], cfg)
    return {"kind_" + kind: mixer, "ln_mlp": L.norm_init(cfg), "mlp": L.mlp_init(ks[1], cfg)}


def apply_layer(params, cfg, kind, x, positions, cache=None):
    x = nn.constrain_batch(x)
    if kind == "attn":
        m = params["kind_attn"]
        h, new_cache = L.attention(m["attn"], L.norm(m["ln"], x, cfg), positions, cfg, cache)
        x = x + h
    else:
        x, new_cache = rglru.rglru_block(params["kind_rec"], cfg, x, cache)
    x = x + L.mlp(params["mlp"], L.norm(params["ln_mlp"], x, cfg), cfg)
    return x, new_cache


def _group(cfg):
    """Pattern-position grouping: returns (kinds, counts, full_periods)."""
    kinds = _layer_kinds(cfg)
    plen = len(cfg.block_pattern or ("rec", "rec", "attn"))
    counts = [len([i for i in range(cfg.n_layers) if i % plen == j]) for j in range(plen)]
    return kinds, counts, min(counts)


def init(key, cfg):
    kinds, counts, _ = _group(cfg)
    plen = len(counts)
    ks = nn.split_keys(key, cfg.n_layers + 2)
    stacks = []
    for j in range(plen):
        idxs = [i for i in range(cfg.n_layers) if i % plen == j]
        layer_params = [init_layer(ks[i], cfg, kinds[i]) for i in idxs]
        stacks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *layer_params))
    return {
        "embed": nn.embed_init(ks[-2], cfg.vocab_padded, cfg.d_model, cfg.dtype),
        "stacks": stacks,
        "ln_f": L.norm_init(cfg),
        "lm_head": nn.dense_init(ks[-1], cfg.d_model, cfg.vocab_padded, cfg.dtype),
    }


def _run_stack(params, cfg, x, positions, caches=None, remat: bool = True):
    kinds, counts, n_full = _group(cfg)
    plen = len(counts)
    pattern = (cfg.block_pattern or ("rec", "rec", "attn"))

    def period(carry, layer_slices):
        x = carry
        new_caches = []
        for j in range(plen):
            lp = layer_slices[2 * j]
            lc = layer_slices[2 * j + 1]
            x, nc = apply_layer(lp, cfg, pattern[j], x, positions, lc)
            new_caches.append(nc)
        return x, tuple(new_caches)

    from repro.models import probe_mode

    probing = probe_mode.enabled()
    fn = jax.checkpoint(period) if (remat and not probing) else period
    xs = []
    for j in range(plen):
        sl = jax.tree.map(lambda a: a[:n_full], params["stacks"][j])
        cl = None if caches is None else jax.tree.map(lambda a: a[:n_full], caches[j])
        xs += [sl, cl]
    x, scanned_caches = jax.lax.scan(fn, x, tuple(xs), unroll=True if probing else 1)

    new_caches = list(scanned_caches) if caches is not None else [None] * plen
    # leftover partial period
    for j in range(plen):
        if counts[j] > n_full:
            lp = jax.tree.map(lambda a: a[n_full], params["stacks"][j])
            lc = None if caches is None else jax.tree.map(lambda a: a[n_full], caches[j])
            x, nc = apply_layer(lp, cfg, pattern[j], x, positions, lc)
            if caches is not None:
                new_caches[j] = jax.tree.map(
                    lambda s, one: jnp.concatenate([s, one[None]], axis=0),
                    new_caches[j], nc,
                )
    return x, (tuple(new_caches) if caches is not None else None)


def forward(params, cfg, tokens, embeds=None, remat: bool = True):
    x = nn.embed(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, _ = _run_stack(params, cfg, x, positions, remat=remat)
    return L.norm(params["ln_f"], x, cfg)


def logits_fn(params, x):
    return nn.linear(params["lm_head"], x)


def make_cache(cfg, batch: int, max_seq: int, dtype=None, page=None,
               n_pages=None):
    dtype = dtype or cfg.dtype
    kinds, counts, _ = _group(cfg)
    plen = len(counts)
    pattern = cfg.block_pattern or ("rec", "rec", "attn")
    r = cfg.rglru_dim or cfg.d_model
    win = min(cfg.window or max_seq, max_seq)
    geom = page_geometry(cfg, max_seq, page) if page is not None else None
    caches = []
    for j in range(plen):
        n = counts[j]
        if pattern[j] == "attn":
            if geom is not None:
                # paged attn stack: all attn stacks share one block-table
                # geometry (same window), so physical ids are pool-global
                c = paging.make_attn_pool(n, n_pages, geom["page"],
                                          cfg.n_kv_heads, cfg.head_dim, dtype)
                c["pos"] = jnp.zeros((n, batch), jnp.int32)
                c.update(paging.make_tables(n, batch, geom["n_bt"]))
            else:
                c = {
                    "k": jnp.zeros((n, batch, win, cfg.n_kv_heads, cfg.head_dim), dtype),
                    "v": jnp.zeros((n, batch, win, cfg.n_kv_heads, cfg.head_dim), dtype),
                    "pos": jnp.zeros((n, batch), jnp.int32),
                    "kpos": jnp.full((n, batch, win), 2**30, jnp.int32),
                }
            caches.append(c)
        else:
            # recurrent state is O(1) per slot — stays slot-striped
            caches.append({
                "h": jnp.zeros((n, batch, r), jnp.float32),
                "conv": jnp.zeros((n, batch, rglru.CONV_K - 1, r), dtype),
            })
    return tuple(caches)


def page_geometry(cfg, max_seq: int, page: int) -> dict:
    """The live KV view per attn stack is the (windowed) ring, not max_seq:
    pages cover `min(window, max_seq)` rows and the ring reuses them in
    place once positions wrap."""
    win = min(cfg.window or max_seq, max_seq)
    return paging.geometry(win, page)


def paged_insert(cfg, pool, stripe, slot, row, scatter_ids, bt_row, n_alloc):
    out = []
    for pc, sc in zip(pool, stripe):
        if paging.is_paged(pc):
            out.append(paging.insert_attn(pc, sc, row, scatter_ids, bt_row,
                                          n_alloc, slot))
        else:
            out.append({k: paging.copy_slot_row(pc[k], sc[k], slot, row, 1)
                        for k in pc})
    return tuple(out)


def paged_release(cfg, pool, slot, page_ids):
    out = []
    for pc in pool:
        if paging.is_paged(pc):
            out.append(paging.release_attn(pc, page_ids, slot))
        else:
            # pristine recurrent state is all-zeros (h/conv)
            out.append({k: paging.reset_slot_row(pc[k], slot, 1) for k in pc})
    return tuple(out)


def cache_batch_axes(cfg, cache):
    """Slot (batch) axis per cache leaf: attn and recurrent stacks alike are
    stacked (n_layers_in_stack, B, ...); paged pool leaves map to None."""
    return tuple(
        paging.paged_axes(c) if paging.is_paged(c)
        else jax.tree.map(lambda _: 1, c)
        for c in cache)


def cache_shard_roles(cfg, cache):
    """Sharding role per cache leaf: paged attn stacks shard their page
    axis, stripe attn stacks their slot axis, recurrent stacks stay
    slot-striped state (batch over dp, feature dim over 'model')."""
    def one(c):
        if paging.is_paged(c):
            return paging.paged_roles(c)
        if "k" in c:  # stripe attn stack
            return {"k": "kv", "v": "kv", "pos": "slot", "kpos": "slot"}
        return {k: "state" for k in c}  # rglru h/conv

    return tuple(one(c) for c in cache)


def prefill(params, cfg, tokens, cache, embeds=None, n_rows=None):
    if n_rows is not None:
        raise ValueError("hybrid prefill cannot be length-bucketed: recurrent"
                         " blocks would integrate the padded rows")
    x = nn.embed(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, new_cache = _run_stack(params, cfg, x, positions, caches=cache)
    return L.norm(params["ln_f"], x, cfg)[:, -1], new_cache


def decode_step(params, cfg, tokens, cache):
    x = nn.embed(params["embed"], tokens)
    # decode position comes from the first attention stack's pos counter
    pattern = cfg.block_pattern or ("rec", "rec", "attn")
    attn_j = pattern.index("attn")
    pos = cache[attn_j]["pos"][0]               # (B,) per-slot positions
    positions = pos.astype(jnp.int32)[:, None]
    x, new_cache = _run_stack(params, cfg, x, positions, caches=cache)
    x = L.norm(params["ln_f"], x, cfg)
    return logits_fn(params, x[:, 0]), new_cache


# serve/spec: hybrid verifies SEQUENTIALLY — one jitted scan of exact
# single-token decode steps.  A parallel multi-token write would clobber
# live rows once the windowed ring wraps mid-verify, and the rglru state
# integrates every token it sees; instead each step snapshots (the attn
# row it is about to overwrite, the recurrent state) so `cache_rollback`
# can restore the rejected suffix bit-exactly.  One weight read per token:
# speculation on hybrid buys acceptance-driven emission (and scheduler
# conformance), not the packed-weight-bandwidth win (serve/README.md).
SPEC_VERIFY = "sequential"


def cache_position(cfg, cache):
    pattern = cfg.block_pattern or ("rec", "rec", "attn")
    return cache[pattern.index("attn")]["pos"][0]


def _spec_snapshot(cfg, cache):
    """Per-stack pre-step snapshot: the attn row the next write hits, or a
    copy of the O(1) recurrent state."""
    win = bool(cfg.window)

    def one(c):
        if "k" in c:  # paged or stripe attention stack
            return paging.snapshot_attn_row(c, window=win)
        return {k: c[k] for k in c}  # rglru h/conv (O(1) per slot)

    return tuple(one(c) for c in cache)


def verify_step(params, cfg, tokens, cache):
    """Sequential speculative verify: replay ``tokens (B, S)`` through S
    exact single-token decode steps inside one jit, collecting per-step
    logits and undo snapshots.  Returns (logits (B, S, vocab), cache,
    undo) with undo leaves step-stacked (S, ...)."""

    def step(carry, tok_i):
        c = carry
        snap = _spec_snapshot(cfg, c)
        logits, c = decode_step(params, cfg, tok_i[:, None], c)
        return c, (logits, snap)

    new_cache, (lg, snaps) = jax.lax.scan(
        step, cache, jnp.moveaxis(tokens, 1, 0))
    return jnp.moveaxis(lg, 0, 1), new_cache, snaps


def cache_rollback(cfg, cache, undo, pos0, keep, n_written):
    """Restore the rejected suffix: attn rows return to their pre-step
    snapshots (reverse step order), recurrent state rewinds to the state
    after exactly ``keep`` accepted tokens."""
    win = bool(cfg.window)
    out = []
    for c, u in zip(cache, undo):
        if "k" in c:
            out.append(paging.restore_attn_rows(c, u, pos0, keep, n_written,
                                                window=win))
        else:
            out.append({k: paging.select_state(u[k], c[k], keep) for k in c})
    return tuple(out)


def hinm_plan(cfg) -> list[PruneSpec]:
    """Plan is resolved per pattern-position stack by the pruning walker."""
    plans = {}
    pattern = cfg.block_pattern or ("rec", "rec", "attn")
    for j, kind in enumerate(pattern):
        specs = []
        if kind == "attn":
            specs += [
                PruneSpec("kind_attn/attn/wq", can_permute_rows=False),
                PruneSpec("kind_attn/attn/wk", can_permute_rows=False),
                PruneSpec("kind_attn/attn/wv", row_blocks=cfg.n_kv_heads,
                          consumers=("kind_attn/attn/wo:gqa",)),
                PruneSpec("kind_attn/attn/wo", can_permute_rows=False),
            ]
        else:
            specs += [
                PruneSpec("kind_rec/" + s.path, can_permute_rows=False)
                for s in rglru.rglru_plan_specs()
            ]
        if cfg.act == "swiglu":
            specs += [
                PruneSpec("mlp/wg", tied=("mlp/wu",), consumers=("mlp/wd",)),
                PruneSpec("mlp/wd", can_permute_rows=False),
            ]
        else:
            specs += [
                PruneSpec("mlp/wu", consumers=("mlp/wd",)),
                PruneSpec("mlp/wd", can_permute_rows=False),
            ]
        plans[j] = specs
    return plans
