"""Cost-probe mode: globally switches model internals from memory-efficient
loops (lax.scan / lax.map) to unrolled/one-shot forms so that XLA's
cost_analysis counts every FLOP (a while-loop body is otherwise counted
ONCE regardless of trip count).

Used only by the roofline harness, which compiles small-depth probe
configs in this mode and extrapolates linearly in depth (and sequence
length for time-recurrent archs). Never enabled at runtime — the unrolled
forms would blow past HBM.
"""
from __future__ import annotations

import contextlib

_ENABLED = False


def enabled() -> bool:
    return _ENABLED


@contextlib.contextmanager
def cost_probe():
    global _ENABLED
    prev = _ENABLED
    _ENABLED = True
    try:
        yield
    finally:
        _ENABLED = prev
