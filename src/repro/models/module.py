"""Minimal functional module system.

Params are nested dicts of jnp arrays (plus PackedHiNM nodes on the serve
path). Every model exposes:

  init(key, cfg)                 -> params
  forward(params, cfg, batch)    -> logits          (training/prefill)
  decode_step(params, cfg, cache, tokens) -> (logits, cache)
  hinm_plan(cfg)                 -> list[PruneSpec] (which projections HiNM
                                     prunes, row-permutation freedom, and
                                     producer->consumer coupling)

Linear weights are stored (n_in, n_out) — `x @ w`. The HiNM format is
defined on (n_out, n_in), so packing operates on w.T; `linear()` dispatches
transparently between dense and packed nodes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.types import PackedHiNM
from repro.kernels import ops as kops

Params = Any  # nested dict pytree


@dataclasses.dataclass(frozen=True)
class PruneSpec:
    """One prunable projection and its permutation coupling.

    path         : '/'-joined path to the linear's param dict (under a layer)
    row_blocks   : OCP is restricted to permutations within contiguous row
                   blocks of n_out/row_blocks (1 = free, n_out//V blocks =
                   effectively no OCP). Used for head-structured outputs.
    can_permute_rows : False for residual-constrained outputs (identity OCP).
    consumers    : paths whose weight *columns* (their n_in) are indexed by
                   this projection's output channels; their columns get
                   permuted by this layer's out_perm before their own
                   packing (free at runtime via vec_idx).
    """

    path: str
    row_blocks: int = 1
    can_permute_rows: bool = True
    consumers: tuple[str, ...] = ()
    # projections whose rows are elementwise-coupled with this one (e.g.
    # SwiGLU gate/up): they share this spec's OCP perm (joint saliency).
    tied: tuple[str, ...] = ()


def uniform_init(key, n_in, n_out, dtype):
    scale = (6.0 / (n_in + n_out)) ** 0.5
    return jax.random.uniform(key, (n_in, n_out), dtype, -scale, scale)


def dense_init(key, n_in: int, n_out: int, dtype=jnp.float32, bias: bool = False):
    p = {"w": uniform_init(key, n_in, n_out, dtype)}
    if bias:
        p["b"] = jnp.zeros((n_out,), dtype)
    return p


def linear(p, x: jax.Array) -> jax.Array:
    """Dense or HiNM-packed projection; packed rows are already consistent
    with consumers (permutations folded offline), so no runtime reorder."""
    if isinstance(p, dict) and isinstance(p.get("w"), PackedHiNM):
        y = kops.hinm_matmul(x, p["w"])
    else:
        w = p["w"]
        y = jnp.einsum("...i,io->...o", x, w.astype(x.dtype))
    if isinstance(p, dict) and "b" in p and p["b"] is not None:
        y = y + p["b"].astype(y.dtype)
    return y


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def get_path(tree: Params, path: str):
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def set_path(tree: Params, path: str, value) -> Params:
    """Functional set — returns a new tree sharing unmodified nodes."""
    parts = path.split("/")

    def rec(node, i):
        if i == len(parts):
            return value
        new = dict(node)
        new[parts[i]] = rec(node[parts[i]], i + 1)
        return new

    return rec(tree, 0)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def constrain(x: jax.Array, roles: tuple) -> jax.Array:
    """Sharding constraint by role per dim ('dp' | 'tp' | None).

    Resolves roles against the active abstract mesh with divisibility
    checks; silently no-ops without a mesh context (CPU smoke tests) and
    degrades any non-divisible dim to replicated.
    """
    from repro import compat

    am = compat.get_abstract_mesh()
    if am is None or am.empty:
        return x
    dp = tuple(a for a in ("pod", "data") if a in am.axis_names)
    spec = []
    for role, dim in zip(roles, x.shape):
        ax = None
        if role == "dp" and dp:
            n = 1
            for a in dp:
                n *= am.shape[a]
            ax = dp if dim % n == 0 else None
        elif role == "tp" and "model" in am.axis_names:
            ax = "model" if dim % am.shape["model"] == 0 else None
        spec.append(ax)
    return jax.lax.with_sharding_constraint(x, jax.sharding.PartitionSpec(*spec))


def constrain_batch(x: jax.Array) -> jax.Array:
    """Pin the leading (batch) dim to the data-parallel mesh axes.

    XLA SPMD propagation can drop the batch sharding around FSDP-sharded
    contractions (replicating activations over 'data'); this constraint at
    block boundaries keeps activations batch-sharded.
    """
    return constrain(x, ("dp",) + (None,) * (x.ndim - 1))
