"""Serve a HiNM-pruned model under a staggered-arrival workload.

  PYTHONPATH=src python examples/serve_hinm.py --requests 10 --slots 4

Prunes a small LM one-shot with gyro-permutation, packs it, and drives the
continuous-batching scheduler with requests that arrive over time with
mixed lengths and sampling params. Reports per-request TTFT / tokens/s /
weight-bytes-per-token plus aggregate throughput, and compares against
the naive static-batching policy on the same workload.
`--compare-dense` also serves the masked-dense model and verifies
token-identical greedy outputs under batching.

Prefix sharing: `--shared-prefix N` prepends one N-token system prompt to
every request — full pages of it are cached once in the paged pool and
refcount-mapped into later slots (copy-on-write for divergent tails), and
the run reports the prefix hit rate and pages shared. `--prefill-chunk C`
splits each admission's unshared suffix into C-row chunks interleaved
with decode steps (long prompts stop spiking co-resident latency).

Observability: `--metrics-json PATH` serves with telemetry enabled and
writes the metrics-registry snapshot (counters / gauges / latency
histograms, kernel dispatch decisions included) as JSON; `--trace-out
PATH` writes the request-lifecycle spans as Chrome trace-event JSON —
open it at https://ui.perfetto.dev to see queued/prefill/decode phases
per request alongside the scheduler's dispatch timeline.

Flight recorder: `--record OUT.jsonl` captures every scheduler decision
(admissions, page maps, spec windows, kernel dispatch) as a JSON-lines
record; `--replay IN.jsonl` rebuilds the workload from such a record and
re-drives a fresh scheduler, asserting event-for-event and
token-for-token identity — run it with the SAME scheduler flags the
record was captured with (a config mismatch surfaces as the first
diverging event).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def build_workload(cfg, n_requests, prompt_len, rng, shared_prefix=0):
    from repro.serve import Request, SamplingParams

    system = rng.integers(0, cfg.vocab, (shared_prefix,)).astype(np.int32)
    reqs = []
    for i in range(n_requests):
        params = SamplingParams(
            max_new_tokens=24 if i % 3 == 0 else 8,
            temperature=0.8 if i % 4 == 3 else 0.0,   # mix greedy + sampled
            top_k=16 if i % 4 == 3 else 0,
        )
        tail = rng.integers(0, cfg.vocab, (prompt_len,)).astype(np.int32)
        reqs.append(Request(
            rid=i,
            prompt=np.concatenate([system, tail]) if shared_prefix else tail,
            params=params,
            arrival=i,  # one new request per scheduler step
        ))
    return reqs


def main():
    from repro.configs.base import load_arch
    from repro.models import zoo
    from repro.serve import Scheduler
    from repro.train import pruning

    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-chunk", type=int, default=4)
    ap.add_argument("--page", type=int, default=16,
                    help="KV pool page size (full pages of a shared prefix "
                         "are what the prefix cache can map)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend one N-token system prompt to every "
                         "request; full pages of it serve from the prefix "
                         "cache instead of recomputing prefill")
    ap.add_argument("--prefill-chunk", type=int, default=None, metavar="C",
                    help="split admission prefill into C-row chunks "
                         "interleaved with decode steps")
    ap.add_argument("--compare-dense", action="store_true")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="draft tokens per verify for the speculative rerun "
                         "(0 disables the comparison)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="serve with telemetry on and dump the metrics "
                         "registry snapshot (JSON) here")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="serve with telemetry on and dump the Chrome "
                         "trace-event JSON here (open in Perfetto)")
    ap.add_argument("--record", default=None, metavar="OUT.jsonl",
                    help="serve with the flight recorder on and dump the "
                         "decision record here (JSON lines)")
    ap.add_argument("--replay", default=None, metavar="IN.jsonl",
                    help="rebuild the workload from a recorded run and "
                         "re-drive it, asserting event- and token-identical "
                         "behaviour (use the same scheduler flags)")
    args = ap.parse_args()

    cfg = load_arch("qwen2_0_5b").reduced(n_layers=4, d_model=256, n_heads=4,
                                          n_kv_heads=2, d_ff=512, vocab=2048,
                                          head_dim=64, max_seq=256)
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    print("pruning with gyro-permutation...")
    newp, masks, packed, report = pruning.prune_model(
        params, cfg, method="gyro", ocp_iters=4, icp_iters=4)
    print(f"mean retained saliency: {report.mean_retained:.4f} "
          f"at {cfg.hinm.total_sparsity:.0%} sparsity")

    max_seq = args.shared_prefix + args.prompt_len + 32
    rng = np.random.default_rng(0)
    workload = build_workload(cfg, args.requests, args.prompt_len, rng,
                              shared_prefix=args.shared_prefix)

    telemetry = None
    if args.metrics_json or args.trace_out:
        from repro.serve import Telemetry

        telemetry = Telemetry(enabled=True)
    sched = Scheduler(cfg, packed, max_slots=args.slots, max_seq=max_seq,
                      decode_chunk=args.decode_chunk, telemetry=telemetry,
                      page=args.page, prefill_chunk=args.prefill_chunk,
                      flightrec=bool(args.record or args.replay))

    if args.replay:
        from repro.serve import replay as replay_record

        rep = replay_record(args.replay, sched)
        print(rep.render())
        rep.assert_equal()
        print("replay OK: event- and token-identical with the record")
        return

    done = sched.run(workload)
    st = sched.stats
    pb = st.packed_param_bytes

    print(f"\n{'rid':>3} {'new':>4} {'temp':>5} {'ttft_ms':>8} {'tok/s':>7} "
          f"{'kB/tok':>7}  reason")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"{r.rid:>3} {r.n_generated:>4} {r.params.temperature:>5.2f} "
              f"{r.ttft * 1e3:>8.1f} {r.tokens_per_second:>7.1f} "
              f"{r.weight_bytes_per_token(pb) / 1e3:>7.1f}  {r.finish_reason}")

    print(f"\ncontinuous: {st.tokens_generated} tokens, "
          f"{st.decode_tokens_per_second:.1f} tok/s decode, "
          f"{st.decode_steps} batched steps, "
          f"{st.finished_at_eos} finished at EOS")
    print(f"weight bytes: packed/dense = {st.weight_bytes_ratio:.3f} "
          f"(~{1 / st.weight_bytes_ratio:.1f}x less HBM traffic per read)")
    print(f"latency: p50 ttft {1e3 * st.ttft_percentile(50):.1f}ms, "
          f"p99 ttft {1e3 * st.ttft_percentile(99):.1f}ms, "
          f"p99 decode step {1e6 * st.step_time_percentile(99):.0f}us")

    if sched.prefix is not None:
        print(f"prefix cache: {st.prefix_hit_tokens} prompt rows served "
              f"from cache ({st.prefix_hit_rate:.1%} hit rate), "
              f"{sched.kv.cow_copies} copy-on-write pages, "
              f"{int(sched.kv.n_shared_pages)} pages shared now, "
              f"{sched.prefix.evictions} evicted under pressure")
        if args.prefill_chunk:
            print(f"chunked prefill: {st.prefill_chunks} chunks over "
                  f"{st.prefill_rows} unshared prompt rows")

    if telemetry is not None:
        if args.metrics_json:
            telemetry.dump_metrics(args.metrics_json)
            print(f"metrics snapshot -> {args.metrics_json}")
        if args.trace_out:
            telemetry.dump_trace(args.trace_out)
            print(f"chrome trace -> {args.trace_out} "
                  f"(open at https://ui.perfetto.dev)")

    if args.record:
        sched.flight.dump(args.record)
        print(f"flight record -> {args.record} "
              f"({len(sched.flight)} events; replay with --replay)")

    static = Scheduler(cfg, packed, max_slots=args.slots, max_seq=max_seq,
                       decode_chunk=args.decode_chunk, policy="static")
    static.run(build_workload(cfg, args.requests, args.prompt_len,
                              np.random.default_rng(0),
                              shared_prefix=args.shared_prefix))
    print(f"static baseline: {static.stats.decode_steps} batched steps "
          f"(continuous saved "
          f"{static.stats.decode_steps - st.decode_steps} full-batch steps)")

    if args.spec_k:
        from repro.serve import SpecConfig

        spec = Scheduler(cfg, packed, max_slots=args.slots, max_seq=max_seq,
                         decode_chunk=args.decode_chunk, page=args.page,
                         prefill_chunk=args.prefill_chunk,
                         spec=SpecConfig(k=args.spec_k))
        spec_reqs = build_workload(cfg, args.requests, args.prompt_len,
                                   np.random.default_rng(0),
                                   shared_prefix=args.shared_prefix)
        spec.run(spec_reqs)
        ss = spec.stats
        by_rid = {r.rid: r for r in spec_reqs}
        same = all(r.tokens == by_rid[r.rid].tokens for r in done)
        print(f"\nspeculative (n-gram, k={args.spec_k}): "
              f"tokens identical: {same}; "
              f"acceptance {ss.acceptance_rate:.3f}, "
              f"{ss.tokens_per_verify_step:.2f} tok/verify, "
              f"bytes/tok {ss.weight_bytes_per_accepted_token / 1e3:.1f}kB "
              f"vs {st.weight_bytes_per_token / 1e3:.1f}kB chunked")
        assert same  # greedy + "match" stochastic reproduce the stream

    if args.compare_dense:
        masked = pruning.apply_masks(newp, masks)
        greedy = [r for r in workload if r.params.temperature <= 0.0]
        dense = Scheduler(cfg, masked, max_slots=args.slots, max_seq=max_seq,
                          decode_chunk=args.decode_chunk, page=args.page,
                          prefill_chunk=args.prefill_chunk)
        dense_reqs = build_workload(cfg, args.requests, args.prompt_len,
                                    np.random.default_rng(0),
                                    shared_prefix=args.shared_prefix)
        dense.run(dense_reqs)
        by_rid = {r.rid: r for r in dense_reqs}
        same = all(r.tokens == by_rid[r.rid].tokens for r in greedy)
        print(f"packed vs masked-dense greedy outputs identical: {same}")
        assert same

    jax.block_until_ready(sched.kv.cache)


if __name__ == "__main__":
    main()
