"""Serve a HiNM-pruned model with batched requests.

  PYTHONPATH=src python examples/serve_hinm.py --batch 8 --new-tokens 24

Prunes a small LM one-shot with gyro-permutation, packs it, and runs
batched prefill+decode, reporting tokens/s and the weight-bandwidth
reduction the packed format delivers (the quantity the TPU kernel turns
into decode speedup). `--compare-dense` also serves the masked-dense model
and verifies token-identical outputs.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def main():
    from repro.configs.base import load_arch
    from repro.data.pipeline import SyntheticLMData
    from repro.models import zoo
    from repro.serve import ServeEngine
    from repro.train import pruning

    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--compare-dense", action="store_true")
    args = ap.parse_args()

    cfg = load_arch("qwen2_0_5b").reduced(n_layers=4, d_model=256, n_heads=4,
                                          n_kv_heads=2, d_ff=512, vocab=2048,
                                          head_dim=64, max_seq=256)
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    print("pruning with gyro-permutation...")
    newp, masks, packed, report = pruning.prune_model(
        params, cfg, method="gyro", ocp_iters=4, icp_iters=4)
    print(f"mean retained saliency: {report.mean_retained:.4f} "
          f"at {cfg.hinm.total_sparsity:.0%} sparsity")

    data = SyntheticLMData(cfg.vocab, args.prompt_len, args.batch, seed=0)
    prompts = np.asarray(data.batch(0)["tokens"], np.int32)

    eng = ServeEngine(cfg, packed, max_seq=args.prompt_len + args.new_tokens + 8)
    out, stats = eng.generate(prompts, max_new_tokens=args.new_tokens)
    print(f"prefill: {stats.prefill_seconds*1e3:.1f} ms for "
          f"{args.batch}x{args.prompt_len} tokens")
    print(f"decode : {stats.decode_tokens_per_second:.1f} tok/s "
          f"({stats.tokens_generated} tokens)")
    print(f"weight bytes: packed/dense = {stats.weight_bytes_ratio:.3f} "
          f"(~{1/stats.weight_bytes_ratio:.1f}x less HBM traffic per token)")

    if args.compare_dense:
        masked = pruning.apply_masks(newp, masks)
        eng_d = ServeEngine(cfg, masked, max_seq=args.prompt_len + args.new_tokens + 8)
        out_d, stats_d = eng_d.generate(prompts, max_new_tokens=args.new_tokens)
        same = np.array_equal(out, out_d)
        print(f"packed vs masked-dense outputs identical: {same}")
        assert same


if __name__ == "__main__":
    main()
