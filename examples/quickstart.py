"""Quickstart: HiNM sparsity + gyro-permutation on a single weight matrix.

  PYTHONPATH=src python examples/quickstart.py

Walks the paper's pipeline end-to-end on one projection:
  1. build a structured weight + saliency,
  2. run gyro-permutation (OCP + tile-wise ICP) and compare retained
     saliency against no-permutation and the unstructured upper bound,
  3. pack to the HiNM format (vals / vec_idx / nm_idx),
  4. verify the packed matmul (XLA fast path AND the Pallas TPU kernel in
     interpret mode) against the masked-dense oracle,
  5. show the compression ratio the serving path enjoys.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import HiNMConfig, packing
from repro.core.baselines import unstructured_retained
from repro.core.gyro import gyro_permute
from repro.kernels import ops, ref


def main():
    rng = np.random.default_rng(0)
    n_out, n_in = 256, 512
    row = np.exp(rng.normal(scale=0.6, size=(n_out, 1)))
    col = np.exp(rng.normal(scale=0.6, size=(1, n_in)))
    w = (rng.normal(size=(n_out, n_in)) * row * col).astype(np.float32)
    sal = np.abs(w)

    cfg = HiNMConfig(v=32, n=2, m=4, vector_sparsity=0.5)
    print(f"HiNM config: V={cfg.v}, {cfg.n}:{cfg.m}, vector sparsity "
          f"{cfg.vector_sparsity:.0%} -> total {cfg.total_sparsity:.0%}")

    noperm = gyro_permute(sal, cfg, run_ocp=False, run_icp=False)
    gyro = gyro_permute(sal, cfg, ocp_iters=12, icp_iters=10,
                        rng=np.random.default_rng(1))
    upper = unstructured_retained(sal, cfg.total_sparsity)
    print(f"retained saliency:  no-perm {noperm.retained_fraction:.4f}  "
          f"gyro {gyro.retained_fraction:.4f}  unstructured-bound {upper:.4f}")

    # pack with the gyro layout (rows permuted, vec_idx = ICP order)
    w_p = jnp.asarray(w[gyro.out_perm])
    packed = packing.pack(w_p, cfg, col_ids=jnp.asarray(gyro.col_order),
                          sal=jnp.asarray(sal[gyro.out_perm]))
    print(f"packed bytes ratio: {packed.packed_bytes() / packed.dense_bytes():.3f} "
          f"(weight HBM traffic at serve time)")

    x = jnp.asarray(rng.normal(size=(8, n_in)).astype(np.float32))
    y_oracle = ref.hinm_spmm_oracle(x, packed)
    y_xla = ops.hinm_matmul(x, packed, backend="xla")
    y_pallas = ops.hinm_matmul(x, packed, backend="interpret")
    print(f"XLA fast path  max err: {float(jnp.abs(y_xla - y_oracle).max()):.2e}")
    print(f"Pallas kernel  max err: {float(jnp.abs(y_pallas - y_oracle).max()):.2e}")
    print("ok")


if __name__ == "__main__":
    main()
