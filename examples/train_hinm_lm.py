"""End-to-end driver: train an LM with gradual HiNM pruning + recovery.

  PYTHONPATH=src python examples/train_hinm_lm.py                  # tiny, fast
  PYTHONPATH=src python examples/train_hinm_lm.py --scale 100m --steps 300

The run: dense warmup -> cubic vector-sparsity ramp -> N:M stage switches
on at --nm-step -> masked-dense recovery, with fault-tolerant loop
(checkpoint/resume) and the gyro permutation refresh at the N:M switch.
Compare `--method noperm` to see the permutation's effect on recovery.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np


def main():
    from repro.configs.base import load_arch
    from repro.data.pipeline import SyntheticLMData
    from repro.launch.mesh import make_host_mesh
    from repro.models import zoo
    from repro.optim import cosine_schedule, make_optimizer
    from repro.train import gradual, loop, steps as tsteps

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--method", default="gyro", choices=["gyro", "noperm", "v1", "v2"])
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/hinm_lm_ckpt")
    args = ap.parse_args()

    base = load_arch("qwen2_0_5b")
    if args.scale == "tiny":
        cfg = base.reduced(max_seq=args.seq)
    else:  # ~100M-parameter config
        cfg = base.reduced(n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
                           d_ff=2048, vocab=32000, head_dim=64,
                           max_seq=args.seq)
    mesh = make_host_mesh()

    params = zoo.init(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params, HiNM target "
          f"{cfg.hinm.total_sparsity:.0%} sparsity, method={args.method}")

    opt = make_optimizer(cfg.optimizer)
    data = SyntheticLMData(cfg.vocab, args.seq, args.batch, seed=0)
    step_fn, _ = tsteps.make_train_step(
        cfg, mesh, optimizer_name=cfg.optimizer,
        lr_fn=cosine_schedule(3e-3, 10, args.steps))
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    sched = gradual.GradualSchedule(
        target=cfg.hinm,
        vector_end_step=args.steps // 3,
        nm_step=args.steps // 2,
        update_every=10,
    )
    mask_cb = gradual.make_mask_schedule(cfg, sched, method=args.method)

    losses = []

    def batches():
        for b in data.iterator():
            yield {k: jnp.asarray(v) for k, v in b.items()}

    state = loop.LoopState(params=params, opt_state=opt.init(params),
                           masks=jax.tree.map(lambda x: None, params))
    lcfg = loop.LoopConfig(total_steps=args.steps,
                           checkpoint_every=max(args.steps // 3, 20),
                           checkpoint_dir=args.ckpt, log_every=10)
    import logging

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    with compat.set_mesh(mesh):
        state = loop.run(state, jitted, batches(), lcfg,
                         on_step=lambda s, m: losses.append(m.get("loss")),
                         mask_schedule=mask_cb)

    dense_best = min(losses[: args.steps // 3])
    final = float(np.mean(losses[-5:]))
    print(f"\nbest dense-phase loss : {dense_best:.4f}")
    print(f"final loss at {cfg.hinm.total_sparsity:.0%} HiNM sparsity: {final:.4f}")
    print(f"recovery gap          : {final - dense_best:+.4f}")


if __name__ == "__main__":
    main()
