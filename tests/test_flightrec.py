"""Flight recorder: ring bounds, replay determinism, first-divergence triage.

The recorder's contract has three legs: (1) the ring buffer keeps the
most recent `capacity` decisions and counts what it dropped, with event
identity excluding wall-clock time; (2) a recorded run is a replay
script — rebuilding the workload from `submit` events and re-driving a
fresh identically-configured scheduler reproduces the event stream and
token streams exactly; (3) two records diff by causal stream (`rid` >
`slot` > global) and a perturbed run — here a forced kernel-dispatch
change — is named at its FIRST diverging event, not discovered as a deep
token mystery.  The crash dump must capture the pool's host-side truth
(free lists, refcounts, block tables, in-flight requests) when the
scheduler dies mid-step, and a small committed record must keep
replaying across commits (the time-travel regression pin).
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.configs.base import load_arch
from repro.models import zoo
from repro.serve import (FlightRecorder, Request, SamplingParams, Scheduler,
                         SpecConfig, diff_records, load_jsonl, replay)
from repro.serve.flightrec import FlightEvent, recorded_tokens
from repro.serve.flightrec.replay import requests_from_record

SMOKE_RECORD = os.path.join(os.path.dirname(__file__), "data",
                            "flightrec_smoke.jsonl")


# ---------------------------------------------------------------------------
# ring buffer + event identity (no model needed)


def test_ring_buffer_bounds_and_dropped():
    rec = FlightRecorder(capacity=8)
    for i in range(20):
        rec.emit("tick", i=i)
    assert len(rec) == 8
    assert rec.seq == 20
    assert rec.dropped == 12
    # the ring kept the most recent window, in sequence order
    assert [ev.data["i"] for ev in rec.events] == list(range(12, 20))
    seqs = [ev.seq for ev in rec.events]
    assert seqs == sorted(seqs) == list(range(12, 20))
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


def test_signature_excludes_wall_clock():
    a = FlightEvent(0, "admit", 1.0, {"group": [1, 2], "bucket": 8})
    b = FlightEvent(5, "admit", 99.0, {"bucket": 8, "group": [1, 2]})
    # different seq, different t, different key order: same decision
    assert a.signature() == b.signature()
    c = FlightEvent(0, "admit", 1.0, {"group": [1, 3], "bucket": 8})
    assert a.signature() != c.signature()


def test_stream_key_priority():
    assert FlightEvent(0, "emit", 0, {"rid": 3, "slot": 1}).stream_key() \
        == ("rid", 3)
    assert FlightEvent(0, "kv_ref", 0, {"slot": 1}).stream_key() == ("slot", 1)
    assert FlightEvent(0, "config", 0, {"page": 16}).stream_key() == ("global",)


def test_jsonl_round_trip(tmp_path):
    rec = FlightRecorder()
    rec.emit("admit", group=[0, 1], bucket=8, overlap=False)
    rec.emit("emit", rid=0, slot=1, tokens=[5, 9])
    path = str(tmp_path / "rec.jsonl")
    rec.dump(path)
    loaded = load_jsonl(path)
    assert [ev.signature() for ev in loaded] \
        == [ev.signature() for ev in rec.events]
    assert [ev.seq for ev in loaded] == [0, 1]
    assert loaded[1].data == {"rid": 0, "slot": 1, "tokens": [5, 9]}


# ---------------------------------------------------------------------------
# diff: causal-stream alignment


def _ev(seq, kind, **data):
    return FlightEvent(seq, kind, 0.0, data)


def test_diff_aligns_by_causal_stream():
    # the same per-request decisions, interleaved differently globally:
    # stream-aligned diff sees no divergence
    a = [_ev(0, "admit", rid=0, bucket=8), _ev(1, "admit", rid=1, bucket=8),
         _ev(2, "emit", rid=0, tokens=[4]), _ev(3, "emit", rid=1, tokens=[7])]
    b = [_ev(0, "admit", rid=1, bucket=8), _ev(1, "admit", rid=0, bucket=8),
         _ev(2, "emit", rid=1, tokens=[7]), _ev(3, "emit", rid=0, tokens=[4])]
    assert diff_records(a, b).equal

    # one request's second event diverges: named with stream + index
    b2 = [_ev(0, "admit", rid=0, bucket=8), _ev(1, "admit", rid=1, bucket=8),
          _ev(2, "emit", rid=0, tokens=[4]), _ev(3, "emit", rid=1, tokens=[8])]
    rep = diff_records(a, b2)
    assert not rep.equal
    assert rep.first.stream == ("rid", 1)
    assert rep.first.index == 1
    assert rep.first.a.data["tokens"] == [7]
    assert rep.first.b.data["tokens"] == [8]
    assert "emit" in rep.first.describe()
    assert "rid" in rep.render()


def test_diff_length_mismatch_is_divergence():
    a = [_ev(0, "emit", rid=0, tokens=[1]), _ev(1, "finish", rid=0, n=1,
                                                tokens=[1], reason="length")]
    rep = diff_records(a, a[:1])
    assert not rep.equal
    assert rep.first.stream == ("rid", 0)
    assert rep.first.a is not None and rep.first.b is None
    assert "<stream ended>" in rep.first.describe()


# ---------------------------------------------------------------------------
# scheduler integration: record -> replay -> diff


@pytest.fixture(scope="module")
def small_model():
    cfg = load_arch("qwen2_0_5b").reduced(n_layers=2, d_model=64, n_heads=4,
                                          n_kv_heads=2, d_ff=128, vocab=128,
                                          head_dim=16)
    return cfg, zoo.init(jax.random.PRNGKey(0), cfg)


def _workload(cfg, n=4, max_new=5):
    rng = np.random.default_rng(7)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
                    params=SamplingParams(max_new_tokens=max_new), arrival=i)
            for i in range(n)]


def _sched(cfg, params, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("decode_chunk", 4)
    kw.setdefault("flightrec", True)
    return Scheduler(cfg, params, **kw)


def test_recorder_off_by_default_and_shared_instance(small_model):
    cfg, params = small_model
    assert Scheduler(cfg, params, max_slots=2, max_seq=64).flight is None
    rec = FlightRecorder()
    assert _sched(cfg, params, flightrec=rec).flight is rec


def test_recording_does_not_change_tokens(small_model):
    cfg, params = small_model
    runs = {}
    for mode in (False, True):
        sched = Scheduler(cfg, params, max_slots=2, max_seq=64,
                          decode_chunk=4, flightrec=mode)
        reqs = _workload(cfg)
        sched.run(reqs)
        runs[mode] = [r.tokens for r in reqs]
    assert runs[True] == runs[False]


def test_record_replay_event_and_token_identical(small_model, tmp_path):
    cfg, params = small_model
    sched = _sched(cfg, params)
    reqs = _workload(cfg)
    sched.run(reqs)
    path = str(tmp_path / "run.jsonl")
    sched.flight.dump(path)

    # the record carries the full workload: prompts, params, arrivals
    rebuilt = requests_from_record(path)
    assert [r.rid for r in rebuilt] == [r.rid for r in reqs]
    assert all((a.prompt == b.prompt).all() for a, b in zip(rebuilt, reqs))
    assert recorded_tokens(path) == {r.rid: r.tokens for r in reqs}

    # replay through a FRESH identically-configured scheduler, from disk
    rep = replay(path, _sched(cfg, params))
    assert rep.events_equal and rep.tokens_equal and rep.ok
    rep.assert_equal()
    assert rep.n_requests == len(reqs)


def test_record_replay_spec_chunked_sharing(small_model, tmp_path):
    """Replay holds across the full admission machinery: speculative
    fused scan + chunked prefill + prefix sharing + async admission."""
    cfg, params = small_model
    kw = dict(page=16, prefill_chunk=4, prefix_share=True,
              spec=SpecConfig(k=2, drafter="ngram"))
    sched = _sched(cfg, params, **kw)
    reqs = _workload(cfg, n=4, max_new=6)
    # shared prefixes so ext_admit / prefix_match events appear
    for r in reqs[1:3]:
        r.prompt = np.concatenate([reqs[0].prompt[:6],
                                   r.prompt[6:]]).astype(np.int32)
    sched.run(reqs)
    kinds = {ev.kind for ev in sched.flight.events}
    assert {"chunk", "spec_window", "graduate"} <= kinds
    path = str(tmp_path / "spec.jsonl")
    sched.flight.dump(path)
    replay(path, _sched(cfg, params, **kw)).assert_equal()


def test_perturbed_run_diff_names_dispatch_first(small_model):
    """The acceptance pin: force the kernel-dispatch decision to differ
    and the triage diff must name the seq-0 `dispatch` event as the first
    divergence — before any token or admission event."""
    from repro.perf_knobs import knobs

    cfg, params = small_model
    base = _sched(cfg, params, page=16)
    base.run(_workload(cfg))
    with knobs(paged_attn="off"):  # forced defer of the paged-attn kernel
        pert = _sched(cfg, params, page=16)
    pert.run(_workload(cfg))
    rep = diff_records(base.flight, pert.flight)
    assert not rep.equal
    assert rep.first.stream == ("global",)
    assert rep.first.a.kind == "dispatch" == rep.first.b.kind
    assert rep.first.a.data["backend"] != rep.first.b.data["backend"]
    assert "dispatch" in rep.first.describe()


def test_replay_rejects_stale_or_nonrecording_scheduler(small_model):
    cfg, params = small_model
    sched = _sched(cfg, params)
    reqs = _workload(cfg, n=2)
    sched.run(reqs)
    record = sched.flight.events
    with pytest.raises(ValueError, match="fresh"):
        replay(record, sched)  # already recorded this workload
    with pytest.raises(ValueError, match="flightrec=True"):
        replay(record, Scheduler(cfg, params, max_slots=2, max_seq=64))


# ---------------------------------------------------------------------------
# crash dump


def test_crash_dump_snapshots_pool_and_requests(small_model, tmp_path):
    cfg, params = small_model
    from repro.serve import Telemetry

    sched = _sched(cfg, params, page=16, telemetry=Telemetry(enabled=True))
    sched.flight.crash_path = str(tmp_path / "crash.json")
    reqs = _workload(cfg, n=3, max_new=6)
    for r in reqs:
        sched.submit(r)
    sched.step()  # admit + first decode chunk: live slots, mapped pages
    boom = RuntimeError("injected mid-step failure")

    def explode(*a, **k):
        raise boom

    sched._decode_and_harvest = explode
    with pytest.raises(RuntimeError, match="injected"):
        sched.step()

    crash = sched.flight.crash
    assert crash is not None
    assert "injected mid-step failure" in crash["error"]
    # in-flight requests with their phase and slot attribution
    assert crash["requests"], "no in-flight requests captured"
    assert {"rid", "phase", "slot", "prefill_cursor"} \
        <= set(crash["requests"][0])
    # the pool's host-side truth: free lists, refcounts, block tables
    pool = crash["pool"]
    assert pool["paged"]
    assert len(pool["page_ref"]) == pool["n_pages"]
    assert pool["block_tables"], "no block tables captured"
    live_pages = {p for pages in pool["block_tables"].values() for p in pages}
    assert all(pool["page_ref"][p] >= 1 for p in live_pages)
    assert pool["n_free_pages"] + pool["n_referenced_pages"] \
        == pool["n_pages"] - 2  # minus the reserved sentinel pair
    assert crash["events_tail"], "no event tail captured"
    # the dump also landed on disk as JSON
    with open(sched.flight.crash_path) as f:
        assert json.load(f)["error"] == crash["error"]
    # the exception path finalized the trace: no dangling open spans
    assert all(s.t1 is not None for s in sched.telemetry.tracer.events)


# ---------------------------------------------------------------------------
# committed smoke record: replay must keep working across commits


def _smoke_scheduler(cfg, params):
    """The exact configuration the committed record was captured with."""
    return Scheduler(cfg, params, max_slots=2, max_seq=64, decode_chunk=4,
                     page=16, flightrec=True)


def _smoke_workload(cfg):
    rng = np.random.default_rng(11)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (6,)).astype(np.int32),
                    params=SamplingParams(max_new_tokens=4), arrival=i)
            for i in range(3)]


def test_committed_smoke_record_replays(small_model):
    """Regenerate with:
    REPRO_REGEN_FLIGHTREC=1 PYTHONPATH=src python -m pytest \
        tests/test_flightrec.py -k smoke -q"""
    cfg, params = small_model
    if os.environ.get("REPRO_REGEN_FLIGHTREC"):
        os.makedirs(os.path.dirname(SMOKE_RECORD), exist_ok=True)
        sched = _smoke_scheduler(cfg, params)
        sched.run(_smoke_workload(cfg))
        sched.flight.dump(SMOKE_RECORD)
    if not os.path.exists(SMOKE_RECORD):
        pytest.skip("no committed smoke record")
    record = load_jsonl(SMOKE_RECORD)
    assert any(ev.kind == "submit" for ev in record)
    assert any(ev.kind == "finish" for ev in record)
    rep = replay(SMOKE_RECORD, _smoke_scheduler(cfg, params))
    rep.assert_equal()
    assert rep.n_events == len(record)
