"""Per-assigned-architecture smoke tests: REDUCED same-family configs run a
forward + train step + decode step on CPU, asserting shapes and no NaNs.
Full configs are exercised only via the dry-run (abstract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, load_arch
from repro.data.pipeline import SyntheticLMData
from repro.models import zoo
from repro.train import steps as tsteps
from repro.optim import make_optimizer
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


def tiny_batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.frontend == "patch":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_tokens, cfg.d_model)), cfg.dtype
        )
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s + cfg.frontend_tokens)), jnp.int32
        )
    elif cfg.frontend == "frames":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), cfg.dtype
        )
        batch["tokens"] = batch["tokens"][:, : s // 4]
        batch["labels"] = batch["labels"][:, : s // 4]
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_decode(arch):
    cfg = load_arch(arch).reduced()
    if cfg.frontend == "patch":
        cfg = cfg.reduced(frontend_tokens=8)
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    batch = tiny_batch(cfg)
    x = zoo.forward(params, cfg, batch["tokens"], embeds=batch.get("embeds"))
    logits = zoo.logits_fn(params, cfg, x[:, -1])
    assert x.shape[-1] == cfg.d_model
    assert logits.shape[-1] == cfg.vocab_padded
    assert not bool(jnp.isnan(x.astype(jnp.float32)).any())

    cache = zoo.make_cache(cfg, 2, 64)
    last, cache = zoo.prefill(params, cfg, batch["tokens"], cache,
                              embeds=batch.get("embeds"))
    lg, cache = zoo.decode_step(params, cfg, batch["tokens"][:, :1], cache)
    assert lg.shape == (2, cfg.vocab_padded)
    assert not bool(jnp.isnan(lg.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ["qwen2_0_5b", "granite_moe_3b_a800m",
                                  "recurrentgemma_9b", "xlstm_125m",
                                  "seamless_m4t_medium"])
def test_arch_train_step_decreases_nothing_nan(arch, mesh):
    cfg = load_arch(arch).reduced()
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(cfg.optimizer)
    opt_state = opt.init(params)
    masks = jax.tree.map(lambda x: None, params)
    step_fn, _ = tsteps.make_train_step(cfg, mesh, optimizer_name=cfg.optimizer)
    jitted = jax.jit(step_fn)
    batch = tiny_batch(cfg)
    p, o, metrics, _ = jitted(params, opt_state, masks, batch, 0, None)
    assert np.isfinite(float(metrics["loss"]))
    p2, o2, m2, _ = jitted(p, o, masks, batch, 1, None)
    assert np.isfinite(float(m2["loss"]))


def test_decode_matches_forward_logits():
    """Greedy decode over cache must agree with teacher-forced forward."""
    cfg = load_arch("qwen2_0_5b").reduced()
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)

    x = zoo.forward(params, cfg, toks)
    full_logits = zoo.logits_fn(params, cfg, x)          # (B, S, V)

    cache = zoo.make_cache(cfg, 2, 32)
    last, cache = zoo.prefill(params, cfg, toks[:, :8], cache)
    prefill_logits = zoo.logits_fn(params, cfg, last)
    np.testing.assert_allclose(
        np.asarray(prefill_logits), np.asarray(full_logits[:, 7]),
        rtol=2e-3, atol=2e-3,
    )
    lg, cache = zoo.decode_step(params, cfg, toks[:, 8:9], cache)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full_logits[:, 8]), rtol=2e-3, atol=2e-3
    )


def test_window_ring_buffer_decode_matches_full():
    """Hybrid local attention with a ring-buffer cache == full-history attn
    once the window bounds the live KV set."""
    cfg = load_arch("recurrentgemma_9b").reduced(window=16, n_layers=3)
    params = zoo.init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 24)), jnp.int32)

    x = zoo.forward(params, cfg, toks)
    full_logits = zoo.logits_fn(params, cfg, x)

    cache = zoo.make_cache(cfg, 1, 16)   # cache holds only the window
    _, cache = zoo.prefill(params, cfg, toks[:, :20], cache)
    lg, cache = zoo.decode_step(params, cfg, toks[:, 20:21], cache)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full_logits[:, 20]), rtol=3e-3, atol=3e-3
    )


@pytest.mark.parametrize("arch", ["bert_base", "deit_base"])
def test_paper_model_configs(arch):
    """The paper's own models (Tables 1/2) load and run reduced smoke."""
    cfg = load_arch(arch).reduced()
    if cfg.frontend == "patch":
        cfg = cfg.reduced(frontend_tokens=8)
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    batch = tiny_batch(cfg)
    x = zoo.forward(params, cfg, batch["tokens"], embeds=batch.get("embeds"))
    assert not bool(jnp.isnan(x.astype(jnp.float32)).any())
