"""Packed HiNM format: exact round-trips and format invariants."""
import jax.numpy as jnp
import numpy as np

from repro.core import packing, sparsity
from repro.core.types import HiNMConfig

from _hypothesis_compat import given, integers, sampled_from, settings


def test_pack_unpack_equals_masked_dense(rng):
    cfg = HiNMConfig(v=8, n=2, m=4, vector_sparsity=0.5)
    w = jnp.asarray(rng.normal(size=(32, 24)).astype(np.float32))
    p = packing.pack(w, cfg)
    rec = packing.unpack(p)
    mask = sparsity.hinm_mask(jnp.abs(w), cfg)
    assert jnp.allclose(rec, w * mask)


def test_pack_respects_explicit_column_order(rng):
    cfg = HiNMConfig(v=8, n=2, m=4, vector_sparsity=0.5)
    w = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
    sal = jnp.abs(w)
    ids = np.asarray(sparsity.kept_column_ids(sal, cfg))
    ids_perm = ids[:, ::-1].copy()  # reverse the ICP order
    p = packing.pack(w, cfg, col_ids=jnp.asarray(ids_perm), sal=sal)
    assert np.array_equal(np.asarray(p.vec_idx), ids_perm)
    rec = packing.unpack(p)
    mask = sparsity.hinm_mask_from_columns(sal, jnp.asarray(ids_perm), cfg)
    assert jnp.allclose(rec, w * mask)


def test_packed_bytes_ratio():
    cfg = HiNMConfig(v=32, n=2, m=4, vector_sparsity=0.5)
    w = jnp.ones((512, 512), jnp.bfloat16)
    p = packing.pack(w, cfg)
    # 75% sparsity: values bytes alone are 25% of dense; indices add a bit
    ratio = p.packed_bytes() / p.dense_bytes()
    assert 0.25 < ratio < 0.45


@settings(max_examples=20, deadline=None)
@given(
    seed=integers(0, 10_000),
    v=sampled_from([8, 16]),
    sv=sampled_from([0.25, 0.5]),
)
def test_property_roundtrip(seed, v, sv):
    cfg = HiNMConfig(v=v, n=2, m=4, vector_sparsity=sv)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(v * 2, 32)).astype(np.float32))
    p = packing.pack(w, cfg)
    rec = packing.unpack(p)
    mask = packing.pack_mask(p)
    # support consistency: reconstruction is w exactly on the mask, 0 off it
    assert jnp.allclose(jnp.where(mask, rec, 0.0), rec)
    assert jnp.allclose(jnp.where(mask, w, 0.0), rec)
    # nm_idx slots are ascending within each group and in [0, M)
    slots = np.asarray(p.nm_idx).reshape(p.t, cfg.v, -1, cfg.n)
    assert (slots >= 0).all() and (slots < cfg.m).all()
    assert (np.diff(slots, axis=-1) > 0).all()
