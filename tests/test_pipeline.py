"""Pipeline-parallel stage executor: toy-scale correctness in a subprocess
(needs >1 device for the 'pod' pipeline axis)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro import compat
mesh = compat.make_mesh((4,), ("pod",))
from repro.distributed.pipeline import pipeline_apply

n_stages, n_micro, mb, d = 4, 8, 2, 16
rng = np.random.default_rng(0)
ws = jnp.asarray(rng.normal(size=(n_stages, d, d)).astype(np.float32) * 0.3)
bs = jnp.asarray(rng.normal(size=(n_stages, d)).astype(np.float32) * 0.1)
x = jnp.asarray(rng.normal(size=(n_micro, mb, d)).astype(np.float32))

def stage_fn(p, xm):
    w, b = p
    return jnp.tanh(xm @ w + b)

with compat.set_mesh(mesh):
    y = jax.jit(lambda p, xx: pipeline_apply(stage_fn, p, xx, mesh))((ws, bs), x)

# sequential reference
ref = x
for sidx in range(n_stages):
    ref = jnp.tanh(ref @ ws[sidx] + bs[sidx])
err = float(jnp.abs(jnp.asarray(y) - ref).max())
assert err < 1e-5, err
print("PIPELINE_OK", err)
"""


def test_pipeline_matches_sequential():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", PROG], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "PIPELINE_OK" in out.stdout, out.stdout + out.stderr
