"""Gyro-permutation behaviour: bijectivity, monotone retention, ablations."""
import numpy as np
import pytest

from repro.core import baselines
from repro.core.gyro import gyro_permute, icp_tile, ocp
from repro.core.types import HiNMConfig

CFG = HiNMConfig(v=8, n=2, m=4, vector_sparsity=0.5)


def structured_sal(rng, m=32, n=32):
    """Saliency with planted row/column structure (gyro has signal to find)."""
    row_scale = np.exp(rng.normal(size=(m, 1)))
    col_scale = np.exp(rng.normal(size=(1, n)))
    return (np.abs(rng.normal(size=(m, n))) * row_scale * col_scale).astype(np.float32)


def test_ocp_returns_bijection(rng):
    sal = structured_sal(rng)
    perm, hist = ocp(sal, CFG, iters=6, rng=rng)
    assert sorted(perm.tolist()) == list(range(32))
    assert all(b >= a - 1e-6 for a, b in zip(hist, hist[1:]))  # monotone


def test_icp_tile_bijection_and_improvement(rng):
    tile = structured_sal(rng, 8, 16)
    order, hist = icp_tile(tile, CFG, iters=8)
    assert sorted(order.tolist()) == list(range(16))
    assert hist[-1] >= hist[0] - 1e-6


def test_gyro_beats_noperm(rng):
    sal = structured_sal(rng, 32, 32)
    base = gyro_permute(sal, CFG, rng=np.random.default_rng(1),
                        run_ocp=False, run_icp=False)
    full = gyro_permute(sal, CFG, ocp_iters=10, icp_iters=10,
                        rng=np.random.default_rng(1))
    assert full.retained >= base.retained
    assert full.retained_fraction <= 1.0


def test_gyro_components_additive(rng):
    """OCP-only and ICP-only each at least match noperm; both together at
    least match each alone (on structured saliency)."""
    sal = structured_sal(rng, 32, 32)
    r = {}
    for name, kw in [
        ("noperm", dict(run_ocp=False, run_icp=False)),
        ("icp", dict(run_ocp=False)),
        ("ocp", dict(run_icp=False)),
        ("gyro", dict()),
    ]:
        r[name] = gyro_permute(sal, CFG, ocp_iters=8, icp_iters=8,
                               rng=np.random.default_rng(2), **kw).retained
    assert r["icp"] >= r["noperm"] - 1e-5
    assert r["ocp"] >= r["noperm"] - 1e-5
    assert r["gyro"] >= max(r["icp"], r["ocp"]) - 1e-3


def test_ablation_variants_run(rng):
    sal = structured_sal(rng, 16, 16)
    v1 = baselines.hinm_v1(sal, CFG, np.random.default_rng(0))
    v2 = baselines.hinm_v2(sal, CFG, np.random.default_rng(0), ocp_iters=4)
    gy = gyro_permute(sal, CFG, ocp_iters=8, icp_iters=8,
                      rng=np.random.default_rng(0))
    for res in (v1, v2, gy):
        assert sorted(res.out_perm.tolist()) == list(range(16))
        assert 0 < res.retained <= res.total
    # the paper's central ablation claim, on structured data
    assert gy.retained >= v1.retained - 1e-3


def test_col_order_is_valid_vec_idx(rng):
    sal = structured_sal(rng, 16, 16)
    res = gyro_permute(sal, CFG, ocp_iters=4, icp_iters=4, rng=rng)
    k = CFG.kept_columns(16)
    assert res.col_order.shape == (2, k)
    for row in res.col_order:
        assert len(set(row.tolist())) == k  # no duplicate columns per tile


def test_unstructured_upper_bounds_hinm(rng):
    sal = structured_sal(rng, 32, 32)
    gy = gyro_permute(sal, CFG, ocp_iters=8, icp_iters=8, rng=rng)
    unst = baselines.unstructured_retained(sal, CFG.total_sparsity)
    assert gy.retained_fraction <= unst + 1e-6
