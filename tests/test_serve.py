"""Serving runtime over packed HiNM weights: compat engine, continuous-
batching scheduler invariants, slot pool reuse, EOS handling, sampler.

Token-equivalence across family x layout x (sharded/unsharded) lives in
`serve_conformance.py` (the reusable harness); this module keeps the
scheduler/pool invariants and borrows its isolated-decode reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from serve_conformance import greedy_isolated

from repro.configs.base import load_arch
from repro.models import zoo
from repro.serve import (ModelDrafter, Request, RequestState, SamplingParams,
                         Scheduler, ServeEngine, SlotKVCache, sampler)
from repro.train import pruning


@pytest.fixture(scope="module")
def pruned_model():
    cfg = load_arch("qwen2_0_5b").reduced(n_layers=2, d_model=64, n_heads=4,
                                          n_kv_heads=2, d_ff=128, vocab=128,
                                          head_dim=16)
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    newp, masks, packed, _ = pruning.prune_model(params, cfg, ocp_iters=2,
                                                 icp_iters=2)
    return cfg, newp, masks, packed


def test_generate_shapes_and_determinism(pruned_model):
    cfg, _, _, packed = pruned_model
    eng = ServeEngine(cfg, packed, max_seq=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    out1, stats = eng.generate(prompts, max_new_tokens=6)
    out2, _ = eng.generate(prompts, max_new_tokens=6)
    assert out1.shape == (2, 6)
    assert np.array_equal(out1, out2)  # greedy = deterministic
    assert (out1 >= 0).all() and (out1 < cfg.vocab).all()
    assert stats.tokens_generated == 12
    assert 0.2 < stats.weight_bytes_ratio < 1.0


def test_packed_decode_matches_masked_dense(pruned_model):
    cfg, newp, masks, packed = pruned_model
    masked = pruning.apply_masks(newp, masks)
    prompts = np.random.default_rng(1).integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    out_dense, _ = ServeEngine(cfg, masked, max_seq=64).generate(prompts, 8)
    out_packed, _ = ServeEngine(cfg, packed, max_seq=64).generate(prompts, 8)
    assert np.array_equal(out_dense, out_packed)


def test_packed_bytes_accounting(pruned_model):
    cfg, _, _, packed = pruned_model
    eng = ServeEngine(cfg, packed, max_seq=32)
    pb, db = eng.packed_bytes()
    assert pb < db  # compression visible at the whole-model level


# ---------------------------------------------------------------------------
# scheduling invariants
# ---------------------------------------------------------------------------


def test_staggered_admission_matches_isolated_greedy(pruned_model):
    """Continuous batching must not change tokens: requests admitted into a
    busy pool at staggered steps decode token-identically to isolated
    batch-1 generation (packed HiNM path)."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (8, 8, 8, 8, 8)]
    sched = Scheduler(cfg, packed, max_slots=2, max_seq=64, decode_chunk=4)
    reqs = [Request(rid=i, prompt=p, params=SamplingParams(max_new_tokens=7),
                    arrival=i) for i, p in enumerate(prompts)]
    done = sched.run(reqs)
    assert sorted(r.rid for r in done) == list(range(5))
    for r in reqs:
        assert r.state is RequestState.FINISHED
        assert r.finish_reason == "length"
        assert r.ttft >= 0 and r.tokens_per_second > 0
        iso = greedy_isolated(cfg, packed, r.prompt, 7, 64)
        assert r.tokens == iso, f"request {r.rid} diverged under batching"
    assert sched.stats.tokens_generated == 5 * 7
    assert sched.stats.requests_finished == 5
    assert sched.stats.weight_bytes_per_token > 0


def test_slot_reuse_matches_fresh_cache(pruned_model):
    """A slot recycled from a finished request must decode exactly like a
    fresh cache: the reset kpos sentinel masks stale K/V to zero."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(11)
    p1 = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab, (6,)).astype(np.int32)

    sched = Scheduler(cfg, packed, max_slots=1, max_seq=64, decode_chunk=4)
    r1 = Request(rid=0, prompt=p1, params=SamplingParams(max_new_tokens=6))
    r2 = Request(rid=1, prompt=p2, params=SamplingParams(max_new_tokens=6),
                 arrival=1)
    sched.run([r1, r2])
    assert r1.slot == r2.slot == 0  # r2 reused r1's slot
    fresh = Scheduler(cfg, packed, max_slots=1, max_seq=64, decode_chunk=4)
    rf = Request(rid=0, prompt=p2, params=SamplingParams(max_new_tokens=6))
    fresh.run([rf])
    assert r2.tokens == rf.tokens


def test_eos_early_exit_and_stats(pruned_model):
    """EOS terminates a slot early, is counted in ServeStats, and does not
    perturb the tokens up to the stop point."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    free_run = greedy_isolated(cfg, packed, prompt, 8, 64)
    eos = free_run[3]  # force a stop 4 tokens in

    sched = Scheduler(cfg, packed, max_slots=2, max_seq=64, decode_chunk=4)
    r_eos = Request(rid=0, prompt=prompt,
                    params=SamplingParams(max_new_tokens=8, eos_id=eos))
    r_full = Request(rid=1, prompt=prompt,
                     params=SamplingParams(max_new_tokens=8))
    sched.run([r_eos, r_full])
    assert r_eos.tokens == free_run[: free_run.index(eos) + 1]
    assert r_eos.finish_reason == "eos"
    assert r_full.tokens == free_run
    assert r_full.finish_reason == "length"
    assert sched.stats.finished_at_eos == 1
    assert sched.stats.requests_finished == 2


def test_cfg_eos_id_flows_through_engine(pruned_model):
    """cfg.eos_id (in-vocab) terminates engine generation; the output row is
    zero-padded past the stop and the stat surfaces the count."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(5)
    prompts = rng.integers(0, cfg.vocab, (1, 8)).astype(np.int32)
    free = greedy_isolated(cfg, packed, prompts[0], 8, 64)
    eos = free[2]
    stop = free.index(eos)  # the chosen id may first occur before index 2
    cfg_eos = cfg.reduced(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=128, head_dim=16, eos_id=eos)
    out, stats = ServeEngine(cfg_eos, packed, max_seq=64).generate(
        prompts, max_new_tokens=8)
    assert out[0, : stop + 1].tolist() == free[: stop + 1]
    assert (out[0, stop + 1 :] == 0).all()
    assert stats.finished_at_eos == 1
    # out-of-vocab eos (the real tokenizer id on a reduced config) = disabled
    assert Scheduler(cfg, packed, max_slots=1, max_seq=64).default_eos == -1


def test_static_policy_gang_admission(pruned_model):
    """The static baseline must not refill freed slots mid-stream."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab, (8,)).astype(np.int32) for _ in range(4)]
    sched = Scheduler(cfg, packed, max_slots=2, max_seq=64, decode_chunk=2,
                      policy="static")
    short = SamplingParams(max_new_tokens=2)
    long = SamplingParams(max_new_tokens=10)
    reqs = [Request(rid=0, prompt=prompts[0], params=long),
            Request(rid=1, prompt=prompts[1], params=short),
            Request(rid=2, prompt=prompts[2], params=short),
            Request(rid=3, prompt=prompts[3], params=short)]
    sched.run(reqs)
    # rid=1 finished early but rid=2/3 waited for the whole gang to drain
    assert reqs[1].finish_time < reqs[2].admit_time
    assert reqs[0].finish_time <= reqs[2].admit_time
    for r in reqs:
        assert r.n_generated == r.params.max_new_tokens


def test_slot_pool_accounting(pruned_model):
    cfg, _, _, packed = pruned_model
    kv = SlotKVCache(cfg, 3, 32)
    assert kv.n_free == 3
    s = kv.acquire()
    assert kv.n_free == 2
    kv.release(s)
    assert kv.n_free == 3
    # reset restores the kpos sentinel so stale keys can never be attended
    assert int(np.asarray(kv.cache["kpos"]).min()) == 2**30


# ---------------------------------------------------------------------------
# paged pool + bucketed admission
# ---------------------------------------------------------------------------


def test_auto_n_pages_gates_admission(pruned_model):
    """The default ``n_pages="auto"`` provisions the pool for occupancy,
    not worst-case capacity — so admission actually gates on free pages.
    (The old default, None = full stripe capacity, never blocked: the
    paged memory win silently vanished unless callers tuned n_pages.)"""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(43)
    sched = Scheduler(cfg, packed, max_slots=2, max_seq=64, decode_chunk=2,
                      page=16)  # n_pages defaults to "auto"
    assert sched.kv.paged
    # occupancy-derived: strictly fewer pages than full stripe capacity
    assert sched.kv.n_alloc_pages < sched.max_slots * sched.kv.n_bt
    # two requests, 3 pages each (20 prompt + 14 new = 34 rows); the 4-page
    # auto pool fits only one at a time although both SLOTS are free
    prompts = [rng.integers(0, cfg.vocab, (20,)).astype(np.int32)
               for _ in range(2)]
    reqs = [Request(rid=i, prompt=p, params=SamplingParams(max_new_tokens=14))
            for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    sched.step()
    assert reqs[0].state is RequestState.DECODING
    assert reqs[1].state is RequestState.QUEUED  # pages gate, not slots
    assert sched.kv.n_free >= 1
    sched.run([])  # r1 drains, its pages refill the list, r2 admits (FIFO)
    iso = [greedy_isolated(cfg, packed, p, 14, 64) for p in prompts]
    assert [r.tokens for r in reqs] == iso
    # post-drain: only the prefix index may retain pages (refcount law),
    # and dropping it returns the pool to pristine
    kv = sched.kv
    assert kv.n_free_pages + kv.n_referenced_pages == kv.n_alloc_pages
    sched.clear_prefix_cache()
    assert kv.n_free_pages == kv.n_alloc_pages


def test_paged_page_reuse_cannot_leak(pruned_model):
    """A freed page rewritten by a new request must not leak rows into any
    lane: release resets the freed pages' kpos to the sentinel (the per-page
    form of the slot-reset argument in serve/README.md), so the recycled
    request decodes exactly like a fresh pool."""
    cfg, _, _, packed = pruned_model
    from repro.models import paging

    rng = np.random.default_rng(19)
    p_long = rng.integers(0, cfg.vocab, (14,)).astype(np.int32)
    p_short = rng.integers(0, cfg.vocab, (6,)).astype(np.int32)

    sched = Scheduler(cfg, packed, max_slots=1, max_seq=64, decode_chunk=4,
                      page=8, n_pages=4)
    r1 = Request(rid=0, prompt=p_long, params=SamplingParams(max_new_tokens=6))
    r2 = Request(rid=1, prompt=p_short, params=SamplingParams(max_new_tokens=6),
                 arrival=1)
    sched.submit(r1)
    sched.step()  # r1 admitted: 3 pages hold real kpos rows
    kpos = np.asarray(sched.kv.cache["kpos"])  # (L, n_pages, page)
    live = sched.kv._slot_pages[0]
    assert len(live) == 3  # ceil((14 + 6) / 8)
    for pid in live:
        assert (kpos[:, pid] < 2**30).any(), f"live page {pid} has no rows"

    sched.submit(r2)
    while sched.n_pending:
        sched.step()
    assert r1.slot == r2.slot == 0  # r2 recycled r1's slot (and pages)

    # every release must have swept its pages' kpos back to the sentinel:
    # with both requests drained and the prefix index dropped (retained
    # pages sweep when their LAST reference goes), no allocatable page may
    # retain real rows
    sched.clear_prefix_cache()
    kpos = np.asarray(sched.kv.cache["kpos"])
    for pid in range(paging.N_RESERVED, sched.kv.n_pages):
        assert (kpos[:, pid] == paging.KPOS_SENTINEL).all(), \
            f"freed page {pid} leaked real kpos rows"

    fresh = Scheduler(cfg, packed, max_slots=1, max_seq=64, decode_chunk=4,
                      page=8, n_pages=4)
    rf = Request(rid=0, prompt=p_short, params=SamplingParams(max_new_tokens=6))
    fresh.run([rf])
    assert r2.tokens == rf.tokens


def test_bucketed_admission_compile_count(pruned_model):
    """>= 8 distinct prompt lengths must compile at most one prefill per
    power-of-two bucket (4 here), not one per length; tokens stay identical
    to isolated decode."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(23)
    lens = [5, 7, 9, 12, 16, 21, 30, 47]
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32) for n in lens]

    sched = Scheduler(cfg, packed, max_slots=len(lens), max_seq=64,
                      decode_chunk=4, page=16)
    assert sched.bucket
    reqs = [Request(rid=i, prompt=p, params=SamplingParams(max_new_tokens=5),
                    arrival=2 * i) for i, p in enumerate(prompts)]
    sched.run(reqs)

    def traces(s):
        return s.telemetry.registry.counter("serve_prefill_traces").value

    assert traces(sched) <= 4  # buckets {8, 16, 32, 64}
    for r in reqs:
        assert r.tokens == greedy_isolated(cfg, packed, r.prompt, 5, 64)

    exact = Scheduler(cfg, packed, max_slots=len(lens), max_seq=64,
                      decode_chunk=4, page=16, bucket=False)
    reqs = [Request(rid=i, prompt=p, params=SamplingParams(max_new_tokens=5),
                    arrival=2 * i) for i, p in enumerate(prompts)]
    exact.run(reqs)
    assert traces(exact) == len(lens)  # one jit per distinct length


def test_first_token_finish_skips_slot_churn(pruned_model):
    """Requests that finish at their first token (EOS at prefill or
    max_new_tokens <= 1) must never acquire a slot: previously they
    dispatched a full template reset into a slot that was never written."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(29)
    prompt = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    first = greedy_isolated(cfg, packed, prompt, 1, 64)[0]

    sched = Scheduler(cfg, packed, max_slots=2, max_seq=64, decode_chunk=4)
    writes_before = sched.kv._slot_pages.copy() if sched.kv.paged else None
    r_one = Request(rid=0, prompt=prompt, params=SamplingParams(max_new_tokens=1))
    r_eos = Request(rid=1, prompt=prompt,
                    params=SamplingParams(max_new_tokens=8, eos_id=first))
    done = sched.run([r_one, r_eos])
    assert {r.rid for r in done} == {0, 1}
    assert r_one.slot is None and r_eos.slot is None
    assert r_one.tokens == [first] and r_eos.tokens == [first]
    assert r_eos.finish_reason == "eos" and r_one.finish_reason == "length"
    assert sched.kv.n_free == 2
    # no pages were ever allocated, so none could have been churned
    assert sched.kv._slot_pages == writes_before == {}


def test_slot_len_tracks_actual_cache_rows(pruned_model):
    """slot_len mirrors real cache rows: prompt rows after insert, +1 per
    decode-emitted token (the newest sampled token's KV lands on the step
    that feeds it back, so it is not yet a row)."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(31)
    prompt = rng.integers(0, cfg.vocab, (9,)).astype(np.int32)
    sched = Scheduler(cfg, packed, max_slots=1, max_seq=64, decode_chunk=2,
                      page=16)
    req = Request(rid=0, prompt=prompt, params=SamplingParams(max_new_tokens=6))
    sched.submit(req)
    finished = sched.step()  # admit (prompt rows) + one 2-step chunk
    assert not finished
    emitted_by_chunks = req.n_generated - 1  # first token came from prefill
    assert sched.kv.slot_len[0] == len(prompt) + emitted_by_chunks
    # device truth: the pos counter counts exactly the written rows
    assert int(np.asarray(sched.kv.cache["pos"])[0, 0]) == sched.kv.slot_len[0]
    assert sched.kv.slot_len[0] <= sched.kv.slot_capacity(0)
    sched.run([])  # drain
    assert sched.kv.slot_len[0] == 0  # released


def test_paged_pool_accounting(pruned_model):
    cfg, _, _, packed = pruned_model
    kv = SlotKVCache(cfg, 2, 64, page=16, n_pages=5)
    assert kv.paged and kv.page == 16 and kv.n_bt == 4
    assert kv.n_free_pages == kv.n_alloc_pages == 5
    assert kv.pages_needed(1) == 1 and kv.pages_needed(17) == 2
    assert kv.pages_needed(1000) == 4  # capped at the view
    assert kv.can_admit(64)
    tight = SlotKVCache(cfg, 2, 64, page=16, n_pages=3)
    assert not tight.can_admit(64)  # needs 4 pages, pool allocates 3
    # stripe mode keeps the PR 2 contract untouched
    kv_stripe = SlotKVCache(cfg, 2, 64)
    assert not kv_stripe.paged
    assert kv_stripe.pool_bytes() > 0


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------


def test_sampler_greedy_topk_temperature():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.1, 3.0, 0.2, -1.0],
                          [9.0, 0.0, 0.0, 0.0]], jnp.float32)
    zero = jnp.zeros((2,))
    keys2 = jax.random.split(key, 2)
    # temperature <= 0 -> greedy, regardless of top_k
    out = sampler.sample(keys2, logits, zero, jnp.asarray([0, 2], jnp.int32))
    assert out.tolist() == [1, 0]
    # top_k=1 sampling == greedy even at high temperature
    out = sampler.sample(keys2, logits, jnp.full((2,), 5.0),
                         jnp.ones((2,), jnp.int32))
    assert out.tolist() == [1, 0]
    # temperature sampling stays inside the top-k set, per slot
    keys = jax.random.split(jax.random.PRNGKey(1), 64)
    draws = np.asarray([sampler.sample(jax.random.split(k, 2), logits,
                                       jnp.full((2,), 1.0),
                                       jnp.asarray([2, 3], jnp.int32))
                        for k in keys])
    assert set(draws[:, 0]) <= {1, 2}
    assert set(draws[:, 1]) <= {0, 1, 2}
    # low temperature concentrates on the mode
    draws_cold = np.asarray([sampler.sample(jax.random.split(k, 2), logits,
                                            jnp.full((2,), 0.05),
                                            zero.astype(jnp.int32))
                             for k in keys])
    assert (draws_cold[:, 0] == 1).mean() > 0.9


def test_sampler_top_p():
    """Nucleus sampling: draws stay inside the smallest prefix of the
    descending distribution whose mass reaches top_p, per slot; <= 0
    disables; composes with top-k."""
    # probs per slot ~ [0.636, 0.234, 0.086, 0.032, 0.012] (distinct ranks)
    logits = jnp.asarray([[4.0, 3.0, 2.0, 1.0, 0.0],
                          [2.0, 1.0, 0.0, -1.0, -2.0]], jnp.float32)
    # top_p=0.7 on slot 0 keeps {0, 1} (0.636 alone < 0.7); slot 1 disabled
    masked = sampler.mask_logits(logits, jnp.zeros((2,), jnp.int32),
                                 jnp.asarray([0.7, 0.0], jnp.float32))
    assert np.isfinite(np.asarray(masked[0])).tolist() == [True, True, False,
                                                           False, False]
    assert np.isfinite(np.asarray(masked[1])).all()
    # the first token always survives, however small top_p is
    tiny = sampler.mask_logits(logits, jnp.zeros((2,), jnp.int32),
                               jnp.full((2,), 1e-6, jnp.float32))
    assert np.isfinite(np.asarray(tiny)).sum(axis=1).tolist() == [1, 1]
    # composes with top-k: k=4 survivors renormalized, then the nucleus —
    # slot 0 keeps {0, 1, 2} (mass 0.881 < 0.95), slot 1 only the mode
    both = sampler.mask_logits(logits, jnp.full((2,), 4, jnp.int32),
                               jnp.asarray([0.95, 0.5], jnp.float32))
    assert np.isfinite(np.asarray(both[0])).tolist() == [True, True, True,
                                                         False, False]
    assert np.isfinite(np.asarray(both[1])).sum() == 1
    # sampled draws respect the nucleus (slot 1 disabled: full vocab legal)
    keys = jax.random.split(jax.random.PRNGKey(3), 64)
    draws = np.asarray([sampler.sample(jax.random.split(k, 2), logits,
                                       jnp.full((2,), 1.0),
                                       jnp.zeros((2,), jnp.int32),
                                       jnp.asarray([0.7, 0.0], jnp.float32))
                        for k in keys])
    assert set(draws[:, 0]) <= {0, 1}
    assert len(set(draws[:, 1])) >= 2  # slot 1 keeps sampling freely


def test_per_slot_rng_stream_independence(pruned_model):
    """A stochastic request's sampled stream must depend only on its seed
    and token index — identical whether it decodes alone or staggered into
    a busy pool (the old per-chunk key split made streams depend on slot
    assignment and co-residents)."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(41)
    prompt = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    others = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
              for n in (5, 11, 6)]
    mk = lambda: SamplingParams(max_new_tokens=10, temperature=0.8, top_k=20,
                                top_p=0.9, seed=123)
    alone = Scheduler(cfg, packed, max_slots=1, max_seq=64, decode_chunk=4)
    r_alone = Request(rid=0, prompt=prompt, params=mk())
    alone.run([r_alone])

    busy = Scheduler(cfg, packed, max_slots=3, max_seq=64, decode_chunk=4)
    reqs = [Request(rid=0, prompt=prompt, params=mk(), arrival=2)]
    reqs += [Request(rid=i + 1, prompt=o, arrival=i,
                     params=SamplingParams(max_new_tokens=8, temperature=0.5,
                                           seed=50 + i))
             for i, o in enumerate(others)]
    busy.run(reqs)
    assert reqs[0].tokens == r_alone.tokens, \
        "sampled stream depends on co-residents/slot assignment"


# ---------------------------------------------------------------------------
# speculative decoding (serve/spec) — request-level behavior; the
# cross-family token-identity matrix lives in serve_conformance.py
# ---------------------------------------------------------------------------


def _spec_workload(cfg, rng, n=4):
    lens = (8, 5, 11, 6)[:n]
    return [rng.integers(0, cfg.vocab, (l,)).astype(np.int32) for l in lens]


def test_spec_stochastic_match_is_stream_identical(pruned_model):
    """"match" acceptance + per-position RNG keys: a speculative stochastic
    request emits the EXACT tokens the non-speculative sampler would —
    temperature, top-k and top-p included."""
    from repro.serve import SpecConfig

    cfg, _, _, packed = pruned_model
    prompts = _spec_workload(cfg, np.random.default_rng(43))
    mk = lambda i: SamplingParams(max_new_tokens=9, temperature=0.7,
                                  top_k=30, top_p=0.9, seed=100 + i)

    def run(spec):
        sched = Scheduler(cfg, packed, max_slots=2, max_seq=64,
                          decode_chunk=4, page=16, spec=spec)
        reqs = [Request(rid=i, prompt=p, params=mk(i), arrival=i)
                for i, p in enumerate(prompts)]
        sched.run(reqs)
        return [r.tokens for r in reqs]

    assert run(SpecConfig(k=3)) == run(None)


def test_spec_rejection_sampling_valid(pruned_model):
    """"reject" acceptance: unbiased rejection sampling emits a different
    (but valid) stream — right count, in-vocab, and the residual draw can
    never re-emit a rejected draft token at its own position."""
    from repro.serve import SpecConfig

    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(47)
    prompt = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    p = SamplingParams(max_new_tokens=12, temperature=0.9, top_k=0,
                       seed=7, spec_accept="reject")
    sched = Scheduler(cfg, packed, max_slots=2, max_seq=64, decode_chunk=4,
                      page=16, spec=SpecConfig(k=3))
    req = Request(rid=0, prompt=prompt, params=p)
    sched.run([req])
    assert len(req.tokens) == 12
    assert all(0 <= t < cfg.vocab for t in req.tokens)
    assert req.spec_verify_steps > 0


def test_spec_per_request_opt_out(pruned_model):
    """spec_k=0 disables speculation for one request inside a speculative
    pool: it rides the verify batch one token at a time and still matches
    non-speculative decode; its neighbors keep speculating."""
    from repro.serve import SpecConfig
    from serve_conformance import greedy_isolated

    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(53)
    p_off = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    p_on = rng.integers(0, cfg.vocab, (6,)).astype(np.int32)
    sched = Scheduler(cfg, packed, max_slots=2, max_seq=64, decode_chunk=4,
                      page=16, spec=SpecConfig(k=3))
    r_off = Request(rid=0, prompt=p_off,
                    params=SamplingParams(max_new_tokens=7, spec_k=0))
    r_on = Request(rid=1, prompt=p_on,
                   params=SamplingParams(max_new_tokens=7))
    sched.run([r_off, r_on])
    assert r_off.tokens == greedy_isolated(cfg, packed, p_off, 7, 64)
    assert r_on.tokens == greedy_isolated(cfg, packed, p_on, 7, 64)
    assert r_off.spec_proposed == 0 and r_off.acceptance_rate == 0.0
    assert r_off.spec_verify_steps > 0  # it rode the verify batch
    assert r_on.spec_proposed > 0


def test_spec_eos_inside_accepted_run(pruned_model):
    """An EOS accepted mid-verify must truncate the emit (tokens after it
    are dropped even if accepted) and finish the request with its rows
    rolled back cleanly."""
    from repro.serve import SpecConfig
    from serve_conformance import greedy_isolated

    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(59)
    prompt = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    free = greedy_isolated(cfg, packed, prompt, 8, 64)
    eos = free[3]
    sched = Scheduler(cfg, packed, max_slots=1, max_seq=64, decode_chunk=4,
                      page=16, spec=SpecConfig(k=3, drafter=ModelDrafter(cfg, packed)))
    req = Request(rid=0, prompt=prompt,
                  params=SamplingParams(max_new_tokens=8, eos_id=eos))
    sched.run([req])
    assert req.tokens == free[: free.index(eos) + 1]
    assert req.finish_reason == "eos"
    assert sched.kv.n_free_pages == sched.kv.n_alloc_pages


def test_spec_stats_accounting(pruned_model):
    """Self-drafting (draft == target) pins the stats algebra: acceptance
    1.0, k+1 tokens per ridden verify, and the packed-weight bytes per
    token shrink by the same factor vs the chunked baseline."""
    from repro.serve import SpecConfig

    cfg, _, _, packed = pruned_model
    prompts = _spec_workload(cfg, np.random.default_rng(61), n=2)
    k = 3

    def run(spec):
        sched = Scheduler(cfg, packed, max_slots=2, max_seq=64,
                          decode_chunk=4, page=16, spec=spec)
        reqs = [Request(rid=i, prompt=p,
                        params=SamplingParams(max_new_tokens=13))
                for i, p in enumerate(prompts)]
        sched.run(reqs)
        return reqs, sched.stats

    reqs, st = run(SpecConfig(k=k, drafter=ModelDrafter(cfg, packed)))
    base_reqs, base = run(None)
    assert [r.tokens for r in reqs] == [r.tokens for r in base_reqs]
    assert st.acceptance_rate == 1.0
    assert st.tokens_per_verify_step == k + 1  # 12 decode tokens = 3 rides
    for r in reqs:
        assert r.acceptance_rate == 1.0
        assert r.tokens_per_verify_step == k + 1
    # one packed read per verify vs one per chunk step: bytes/token drops
    # by exactly the ratio of forwards executed
    assert st.weight_bytes_per_accepted_token < base.weight_bytes_per_token
    ratio = st.weight_bytes_per_accepted_token / base.weight_bytes_per_token
    assert ratio == pytest.approx(st.verify_steps / base.decode_steps)


def test_spec_fused_dispatch_count(pruned_model):
    """The fused loop's whole point, pinned like the prefill compile-count
    test: one device dispatch covers ALL of a step's draft/verify cycles
    (draft -> verify -> accept -> rollback -> history, device-resident),
    where the unfused chain pays a draft jit, a verify jit and a rollback
    dispatch per cycle. Tokens must not move between the two."""
    from repro.serve import SpecConfig

    cfg, _, _, packed = pruned_model
    prompts = _spec_workload(cfg, np.random.default_rng(67))

    def run(fused):
        sched = Scheduler(cfg, packed, max_slots=2, max_seq=64,
                          decode_chunk=4, page=16,
                          spec=SpecConfig(k=3, fused=fused))
        reqs = [Request(rid=i, prompt=p,
                        params=SamplingParams(max_new_tokens=9), arrival=i)
                for i, p in enumerate(prompts)]
        sched.run(reqs)
        d = sched.telemetry.registry.counter("serve_spec_dispatches").value
        return [r.tokens for r in reqs], d, sched

    toks_f, d_f, s_f = run(True)
    toks_u, d_u, s_u = run(False)
    assert toks_f == toks_u
    assert s_f.stats.verify_steps > 0
    # fused: one dispatch per decode step, each covering _spec_cycles
    # verify cycles — strictly under one dispatch per cycle
    assert d_f * s_f._spec_cycles == s_f.stats.verify_steps
    assert d_f < s_f.stats.verify_steps
    # unfused: at least draft + verify dispatches for every cycle
    assert d_u >= 2 * s_u.stats.verify_steps
    # the draft wall-time split only exists where draft dispatches exist
    assert s_f.stats.spec_draft_seconds == 0.0
    assert s_u.stats.spec_draft_seconds > 0.0


def test_async_admission_overlaps_decode(pruned_model):
    """Double-buffered admission: while a decode chunk is in flight the
    scheduler prepares the next admission group (host arrays + prefill
    dispatch) and defers the blocking first-token sync to the next step
    boundary. Tokens must match synchronous admission exactly, the overlap
    path must actually engage, and no blocking sync may land while a chunk
    is in flight (the `serve_inflight_syncs` canary)."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(71)
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in (8, 5, 11, 6, 9, 7)]

    def run(async_admission):
        sched = Scheduler(cfg, packed, max_slots=2, max_seq=64,
                          decode_chunk=4, page=16,
                          async_admission=async_admission)
        reqs = [Request(rid=i, prompt=p,
                        params=SamplingParams(max_new_tokens=8), arrival=i)
                for i, p in enumerate(prompts)]
        sched.run(reqs)
        c = sched.telemetry.registry.counter
        return ([r.tokens for r in reqs],
                c("serve_overlap_admissions").value,
                c("serve_inflight_syncs").value)

    toks_async, overlaps, inflight = run(True)
    toks_sync, overlaps_sync, _ = run(False)
    assert toks_async == toks_sync
    assert overlaps > 0          # the overlap path actually engaged
    assert inflight == 0         # never blocked on a sync mid-chunk
    assert overlaps_sync == 0    # the knob really gates the path
