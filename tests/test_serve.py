"""Serving runtime over packed HiNM weights: compat engine, continuous-
batching scheduler invariants, slot pool reuse, EOS handling, sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import load_arch
from repro.models import zoo
from repro.serve import (Request, RequestState, SamplingParams, Scheduler,
                         ServeEngine, SlotKVCache, sampler)
from repro.train import pruning


def greedy_isolated(cfg, params, prompt, n, max_seq, eos=-1):
    """Reference decode: raw batch-1 prefill + python token loop."""
    cache = zoo.make_cache(cfg, 1, max_seq)
    last, cache = zoo.prefill(params, cfg, jnp.asarray(prompt[None]), cache)
    lg = zoo.logits_fn(params, cfg, last)[:, : cfg.vocab]
    toks = [int(jnp.argmax(lg, -1)[0])]
    while len(toks) < n and toks[-1] != eos:
        lg, cache = zoo.decode_step(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[:, : cfg.vocab], -1)[0]))
    return toks


@pytest.fixture(scope="module")
def pruned_model():
    cfg = load_arch("qwen2_0_5b").reduced(n_layers=2, d_model=64, n_heads=4,
                                          n_kv_heads=2, d_ff=128, vocab=128,
                                          head_dim=16)
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    newp, masks, packed, _ = pruning.prune_model(params, cfg, ocp_iters=2,
                                                 icp_iters=2)
    return cfg, newp, masks, packed


def test_generate_shapes_and_determinism(pruned_model):
    cfg, _, _, packed = pruned_model
    eng = ServeEngine(cfg, packed, max_seq=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    out1, stats = eng.generate(prompts, max_new_tokens=6)
    out2, _ = eng.generate(prompts, max_new_tokens=6)
    assert out1.shape == (2, 6)
    assert np.array_equal(out1, out2)  # greedy = deterministic
    assert (out1 >= 0).all() and (out1 < cfg.vocab).all()
    assert stats.tokens_generated == 12
    assert 0.2 < stats.weight_bytes_ratio < 1.0


def test_packed_decode_matches_masked_dense(pruned_model):
    cfg, newp, masks, packed = pruned_model
    masked = pruning.apply_masks(newp, masks)
    prompts = np.random.default_rng(1).integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    out_dense, _ = ServeEngine(cfg, masked, max_seq=64).generate(prompts, 8)
    out_packed, _ = ServeEngine(cfg, packed, max_seq=64).generate(prompts, 8)
    assert np.array_equal(out_dense, out_packed)


def test_packed_bytes_accounting(pruned_model):
    cfg, _, _, packed = pruned_model
    eng = ServeEngine(cfg, packed, max_seq=32)
    pb, db = eng.packed_bytes()
    assert pb < db  # compression visible at the whole-model level


# ---------------------------------------------------------------------------
# scheduling invariants
# ---------------------------------------------------------------------------


def test_staggered_admission_matches_isolated_greedy(pruned_model):
    """Continuous batching must not change tokens: requests admitted into a
    busy pool at staggered steps decode token-identically to isolated
    batch-1 generation (packed HiNM path)."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (8, 8, 8, 8, 8)]
    sched = Scheduler(cfg, packed, max_slots=2, max_seq=64, decode_chunk=4)
    reqs = [Request(rid=i, prompt=p, params=SamplingParams(max_new_tokens=7),
                    arrival=i) for i, p in enumerate(prompts)]
    done = sched.run(reqs)
    assert sorted(r.rid for r in done) == list(range(5))
    for r in reqs:
        assert r.state is RequestState.FINISHED
        assert r.finish_reason == "length"
        assert r.ttft >= 0 and r.tokens_per_second > 0
        iso = greedy_isolated(cfg, packed, r.prompt, 7, 64)
        assert r.tokens == iso, f"request {r.rid} diverged under batching"
    assert sched.stats.tokens_generated == 5 * 7
    assert sched.stats.requests_finished == 5
    assert sched.stats.weight_bytes_per_token > 0


def test_slot_reuse_matches_fresh_cache(pruned_model):
    """A slot recycled from a finished request must decode exactly like a
    fresh cache: the reset kpos sentinel masks stale K/V to zero."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(11)
    p1 = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab, (6,)).astype(np.int32)

    sched = Scheduler(cfg, packed, max_slots=1, max_seq=64, decode_chunk=4)
    r1 = Request(rid=0, prompt=p1, params=SamplingParams(max_new_tokens=6))
    r2 = Request(rid=1, prompt=p2, params=SamplingParams(max_new_tokens=6),
                 arrival=1)
    sched.run([r1, r2])
    assert r1.slot == r2.slot == 0  # r2 reused r1's slot
    fresh = Scheduler(cfg, packed, max_slots=1, max_seq=64, decode_chunk=4)
    rf = Request(rid=0, prompt=p2, params=SamplingParams(max_new_tokens=6))
    fresh.run([rf])
    assert r2.tokens == rf.tokens


def test_eos_early_exit_and_stats(pruned_model):
    """EOS terminates a slot early, is counted in ServeStats, and does not
    perturb the tokens up to the stop point."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    free_run = greedy_isolated(cfg, packed, prompt, 8, 64)
    eos = free_run[3]  # force a stop 4 tokens in

    sched = Scheduler(cfg, packed, max_slots=2, max_seq=64, decode_chunk=4)
    r_eos = Request(rid=0, prompt=prompt,
                    params=SamplingParams(max_new_tokens=8, eos_id=eos))
    r_full = Request(rid=1, prompt=prompt,
                     params=SamplingParams(max_new_tokens=8))
    sched.run([r_eos, r_full])
    assert r_eos.tokens == free_run[: free_run.index(eos) + 1]
    assert r_eos.finish_reason == "eos"
    assert r_full.tokens == free_run
    assert r_full.finish_reason == "length"
    assert sched.stats.finished_at_eos == 1
    assert sched.stats.requests_finished == 2


def test_cfg_eos_id_flows_through_engine(pruned_model):
    """cfg.eos_id (in-vocab) terminates engine generation; the output row is
    zero-padded past the stop and the stat surfaces the count."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(5)
    prompts = rng.integers(0, cfg.vocab, (1, 8)).astype(np.int32)
    free = greedy_isolated(cfg, packed, prompts[0], 8, 64)
    eos = free[2]
    stop = free.index(eos)  # the chosen id may first occur before index 2
    cfg_eos = cfg.reduced(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=128, head_dim=16, eos_id=eos)
    out, stats = ServeEngine(cfg_eos, packed, max_seq=64).generate(
        prompts, max_new_tokens=8)
    assert out[0, : stop + 1].tolist() == free[: stop + 1]
    assert (out[0, stop + 1 :] == 0).all()
    assert stats.finished_at_eos == 1
    # out-of-vocab eos (the real tokenizer id on a reduced config) = disabled
    assert Scheduler(cfg, packed, max_slots=1, max_seq=64).default_eos == -1


def test_static_policy_gang_admission(pruned_model):
    """The static baseline must not refill freed slots mid-stream."""
    cfg, _, _, packed = pruned_model
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab, (8,)).astype(np.int32) for _ in range(4)]
    sched = Scheduler(cfg, packed, max_slots=2, max_seq=64, decode_chunk=2,
                      policy="static")
    short = SamplingParams(max_new_tokens=2)
    long = SamplingParams(max_new_tokens=10)
    reqs = [Request(rid=0, prompt=prompts[0], params=long),
            Request(rid=1, prompt=prompts[1], params=short),
            Request(rid=2, prompt=prompts[2], params=short),
            Request(rid=3, prompt=prompts[3], params=short)]
    sched.run(reqs)
    # rid=1 finished early but rid=2/3 waited for the whole gang to drain
    assert reqs[1].finish_time < reqs[2].admit_time
    assert reqs[0].finish_time <= reqs[2].admit_time
    for r in reqs:
        assert r.n_generated == r.params.max_new_tokens


def test_slot_pool_accounting(pruned_model):
    cfg, _, _, packed = pruned_model
    kv = SlotKVCache(cfg, 3, 32)
    assert kv.n_free == 3
    s = kv.acquire()
    assert kv.n_free == 2
    kv.release(s)
    assert kv.n_free == 3
    # reset restores the kpos sentinel so stale keys can never be attended
    assert int(np.asarray(kv.cache["kpos"]).min()) == 2**30


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------


def test_sampler_greedy_topk_temperature():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.1, 3.0, 0.2, -1.0],
                          [9.0, 0.0, 0.0, 0.0]], jnp.float32)
    zero = jnp.zeros((2,))
    # temperature <= 0 -> greedy, regardless of top_k
    out = sampler.sample(key, logits, zero, jnp.asarray([0, 2], jnp.int32))
    assert out.tolist() == [1, 0]
    # top_k=1 sampling == greedy even at high temperature
    out = sampler.sample(key, logits, jnp.full((2,), 5.0),
                         jnp.ones((2,), jnp.int32))
    assert out.tolist() == [1, 0]
    # temperature sampling stays inside the top-k set, per slot
    keys = jax.random.split(jax.random.PRNGKey(1), 64)
    draws = np.asarray([sampler.sample(k, logits, jnp.full((2,), 1.0),
                                       jnp.asarray([2, 3], jnp.int32))
                        for k in keys])
    assert set(draws[:, 0]) <= {1, 2}
    assert set(draws[:, 1]) <= {0, 1, 2}
    # low temperature concentrates on the mode
    draws_cold = np.asarray([sampler.sample(k, logits, jnp.full((2,), 0.05),
                                            zero.astype(jnp.int32))
                             for k in keys])
    assert (draws_cold[:, 0] == 1).mean() > 0.9
