"""Serving engine over packed HiNM weights."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import load_arch
from repro.models import zoo
from repro.serve import ServeEngine
from repro.train import pruning


@pytest.fixture(scope="module")
def pruned_model():
    cfg = load_arch("qwen2_0_5b").reduced(n_layers=2, d_model=64, n_heads=4,
                                          n_kv_heads=2, d_ff=128, vocab=128,
                                          head_dim=16)
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    newp, masks, packed, _ = pruning.prune_model(params, cfg, ocp_iters=2,
                                                 icp_iters=2)
    return cfg, newp, masks, packed


def test_generate_shapes_and_determinism(pruned_model):
    cfg, _, _, packed = pruned_model
    eng = ServeEngine(cfg, packed, max_seq=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    out1, stats = eng.generate(prompts, max_new_tokens=6)
    out2, _ = eng.generate(prompts, max_new_tokens=6)
    assert out1.shape == (2, 6)
    assert np.array_equal(out1, out2)  # greedy = deterministic
    assert (out1 >= 0).all() and (out1 < cfg.vocab).all()
    assert stats.tokens_generated == 12
    assert 0.2 < stats.weight_bytes_ratio < 1.0


def test_packed_decode_matches_masked_dense(pruned_model):
    cfg, newp, masks, packed = pruned_model
    masked = pruning.apply_masks(newp, masks)
    prompts = np.random.default_rng(1).integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    out_dense, _ = ServeEngine(cfg, masked, max_seq=64).generate(prompts, 8)
    out_packed, _ = ServeEngine(cfg, packed, max_seq=64).generate(prompts, 8)
    assert np.array_equal(out_dense, out_packed)


def test_packed_bytes_accounting(pruned_model):
    cfg, _, _, packed = pruned_model
    eng = ServeEngine(cfg, packed, max_seq=32)
    pb, db = eng.packed_bytes()
    assert pb < db  # compression visible at the whole-model level
