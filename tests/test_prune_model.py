"""Model-level pruning walker: permutation folding preserves the function;
packed model == masked-dense model; ablation methods run end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.types import HiNMConfig
from repro.models import zoo
from repro.train import pruning

BASE = dict(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, max_seq=64, dtype=jnp.float32,
    hinm=HiNMConfig(v=8, n=2, m=4, vector_sparsity=0.5),
)

CONFIGS = [
    ArchConfig(name="dense", family="dense", **BASE),
    ArchConfig(name="moe", family="moe", n_experts=2, top_k=1, **BASE),
    ArchConfig(name="hybrid", family="hybrid", block_pattern=("rec", "rec", "attn"),
               window=16, rglru_dim=64, **{**BASE, "n_layers": 5}),
    ArchConfig(name="ssm", family="ssm", block_pattern=("mlstm", "slstm"),
               **{**BASE, "d_ff": 0, "n_kv_heads": 4}),
    ArchConfig(name="encdec", family="encdec", n_enc_layers=2,
               **{**BASE, "n_kv_heads": 4}),
]


def _setup(cfg):
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    emb = None
    if cfg.family == "encdec":
        emb = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model), cfg.dtype)
    return params, tokens, emb


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: c.name)
def test_perm_folding_preserves_function(cfg):
    params, tokens, emb = _setup(cfg)
    y0 = zoo.forward(params, cfg, tokens, embeds=emb)
    newp, masks, packed, report = pruning.prune_model(
        params, cfg, method="gyro", ocp_iters=3, icp_iters=3
    )
    y1 = zoo.forward(newp, cfg, tokens, embeds=emb)
    err = float(jnp.abs(y1 - y0).max() / (jnp.abs(y0).max() + 1e-9))
    assert err < 1e-4, f"{cfg.name}: permutation folding changed the function"
    assert 0.0 < report.mean_retained < 1.0


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: c.name)
def test_packed_equals_masked_dense(cfg):
    params, tokens, emb = _setup(cfg)
    newp, masks, packed, _ = pruning.prune_model(
        params, cfg, method="gyro", ocp_iters=2, icp_iters=2
    )
    masked = pruning.apply_masks(newp, masks)
    y2 = zoo.forward(masked, cfg, tokens, embeds=emb)
    y3 = zoo.forward(packed, cfg, tokens, embeds=emb)
    err = float(jnp.abs(y3 - y2).max() / (jnp.abs(y2).max() + 1e-9))
    assert err < 1e-4, f"{cfg.name}: packed path != masked dense"


def test_mask_sparsity_level():
    cfg = CONFIGS[0]
    params, _, _ = _setup(cfg)
    _, masks, _, _ = pruning.prune_model(params, cfg, method="noperm",
                                         ocp_iters=1, icp_iters=1)
    leaves = [m for m in jax.tree.leaves(masks) if m is not None]
    dens = np.mean([float(np.asarray(m).mean()) for m in leaves])
    assert abs(dens - 0.25) < 0.02  # 75% HiNM sparsity


@pytest.mark.parametrize("method", ["noperm", "icp_only", "v1", "v2"])
def test_methods_run_and_gyro_wins(method):
    cfg = CONFIGS[0]
    params, _, _ = _setup(cfg)
    _, _, _, rep = pruning.prune_model(params, cfg, method=method,
                                       ocp_iters=2, icp_iters=2)
    _, _, _, rep_gyro = pruning.prune_model(params, cfg, method="gyro",
                                            ocp_iters=4, icp_iters=4)
    assert rep_gyro.mean_retained >= rep.mean_retained - 5e-3


def test_abstract_shapes_match_real():
    """abstract_masks / abstract_packed must predict the walker's shapes."""
    from repro.train import abstract as abst

    cfg = CONFIGS[0]
    params, _, _ = _setup(cfg)
    newp, masks, packed, _ = pruning.prune_model(params, cfg, ocp_iters=1,
                                                 icp_iters=1)
    pshape = jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0), cfg))
    am = abst.abstract_masks(pshape, cfg)
    ap = abst.abstract_packed(pshape, cfg)
    for real, abstr in ((masks, am), (packed, ap)):
        rl = jax.tree.leaves(real)
        al = jax.tree.leaves(abstr)
        assert len(rl) == len(al)
        for r, a in zip(rl, al):
            assert tuple(r.shape) == tuple(a.shape), (r.shape, a.shape)
