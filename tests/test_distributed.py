"""Distribution layer: sharding-rule validity for every arch x mesh, and an
8-fake-device pjit execution in a subprocess (device count is locked at
first jax import, so the multi-device run must be out-of-process)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ARCH_IDS, load_arch
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import zoo
from repro.optim import make_optimizer
from repro.train import abstract as abst

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def meshes():
    # AbstractMesh: spec resolution without needing 512 real devices
    return [compat.abstract_mesh((16, 16), ("data", "model")),
            compat.abstract_mesh((2, 16, 16), ("pod", "data", "model"))]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch, meshes):
    """Every resolved spec divides its dim — for all archs and both meshes."""
    cfg = load_arch(arch)
    pshape = jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0), cfg))
    for mesh in meshes:
        for tree in (pshape, abst.abstract_packed(pshape, cfg)):
            specs = shd.param_specs(tree, mesh, cfg)
            flat_l = jax.tree.leaves(tree)
            flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
            assert len(flat_l) == len(flat_s)
            for leaf, spec in zip(flat_l, flat_s):
                for dim, ax in zip(leaf.shape, tuple(spec)):
                    if ax is None:
                        continue
                    n = 1
                    for a in (ax if isinstance(ax, tuple) else (ax,)):
                        n *= mesh.shape[a]
                    assert dim % n == 0, (arch, leaf.shape, tuple(spec))


@pytest.mark.parametrize("arch", ["qwen2_5_14b", "grok_1_314b"])
def test_opt_state_specs_match_shapes(arch, meshes):
    cfg = load_arch(arch)
    pshape = jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0), cfg))
    opt = make_optimizer(cfg.optimizer)
    oshape = jax.eval_shape(opt.init, pshape)
    pspecs = shd.param_specs(pshape, meshes[0], cfg)
    ospecs = shd.opt_state_specs(oshape, pspecs)
    flat_o = jax.tree.leaves(oshape)
    flat_s = jax.tree.leaves(ospecs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_o) == len(flat_s)
    for leaf, spec in zip(flat_o, flat_s):
        assert len(tuple(spec)) in (0, leaf.ndim)


SUBPROCESS_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import compat
mesh = compat.make_mesh((4, 2), ("data", "model"))
from repro.configs.base import load_arch
from repro.models import zoo
from repro.optim import make_optimizer
from repro.train import steps as tsteps
from repro.data.pipeline import SyntheticLMData

cfg = load_arch("qwen2_0_5b").reduced(n_layers=2, d_model=64, n_heads=4,
                                      n_kv_heads=2, d_ff=128, vocab=256,
                                      head_dim=16)
with compat.set_mesh(mesh):
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adamw")
    opt_state = opt.init(params)
    masks = jax.tree.map(lambda x: None, params)
    data = SyntheticLMData(cfg.vocab, 32, 8, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    pshape = jax.eval_shape(lambda: params)
    oshape = jax.eval_shape(lambda: opt_state)
    bshape = jax.eval_shape(lambda: batch)
    step_fn, _ = tsteps.make_train_step(cfg, mesh)
    jitted, in_specs, _ = tsteps.shard_train_step(step_fn, cfg, mesh, pshape, oshape,
                                                  masks, bshape, donate=False)
    from jax.sharding import NamedSharding, PartitionSpec as P
    named = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, named(in_specs[0]))
    opt_state = jax.device_put(opt_state, named(in_specs[1]))
    batch = jax.device_put(batch, named(in_specs[3]))
    losses = []
    for i in range(3):
        params, opt_state, metrics, _ = jitted(params, opt_state, masks, batch, i, None)
        losses.append(float(metrics["loss"]))
assert np.isfinite(losses).all(), losses
assert losses[2] < losses[0], losses
print("PJIT_OK", losses[0], losses[2])
"""


def test_pjit_train_step_executes_on_8_devices():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_PROG], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "PJIT_OK" in out.stdout, out.stdout + out.stderr


def test_batch_and_cache_specs():
    mesh = compat.abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    cfg = load_arch("qwen2_5_14b")
    batch = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32),
             "odd": jax.ShapeDtypeStruct((3, 4), jnp.float32)}
    bs = shd.batch_specs(batch, mesh)
    assert tuple(bs["tokens"])[0] == ("pod", "data")
    assert tuple(bs["odd"]) == (None, None)

    cache = jax.eval_shape(lambda: zoo.make_cache(cfg, 128, 4096))
    cs = shd.cache_specs(cache, mesh, cfg)
    kspec = tuple(cs["k"])
    assert kspec[1] == ("pod", "data")
    assert "model" in (kspec[2], kspec[3])


def test_cache_specs_paged_layout():
    """Paged decode caches resolve on a data-only serving mesh: pool leaves
    shard their PAGE axis, block tables / counters their slot axis; a
    non-divisible page count degrades to replication; the xlstm recurrent
    tree (no attention leaves) resolves instead of crashing."""
    from repro.models import paging

    mesh = compat.abstract_mesh((4,), ("data",))
    cfg = load_arch("qwen2_0_5b").reduced(n_layers=2, d_model=64, n_heads=4,
                                          n_kv_heads=2, d_ff=128, vocab=128,
                                          head_dim=16)
    geom = paging.shard_geometry(10, 4)
    assert geom["n_pages"] % 4 == 0 and geom["n_pages"] >= 12
    cache = jax.eval_shape(lambda: zoo.make_cache(
        cfg, 4, 64, page=16, n_pages=geom["n_pages"]))
    cs = shd.cache_specs(cache, mesh, cfg)
    for pool in ("k", "v", "kpos"):   # (L, n_pages, page, ...)
        assert tuple(cs[pool])[1] == "data", pool
        assert tuple(cs[pool])[2] is None, pool  # never split inside a page
    for slot in ("bt", "alloc", "pos"):  # (L, B[, n_bt])
        assert tuple(cs[slot])[1] == "data", slot

    # page count not divisible by the mesh -> pool replicates, slots keep
    # their batch sharding (the rule engine never emits an invalid spec)
    odd = jax.eval_shape(lambda: zoo.make_cache(cfg, 4, 64, page=16, n_pages=13))
    co = shd.cache_specs(odd, mesh, cfg)
    assert tuple(co["k"])[1] is None
    assert tuple(co["bt"])[1] == "data"

    # pure-recurrent family: every leaf is state (batch over dp); this is
    # the in-process half of the xlstm stripe-fallback regression
    xcfg = load_arch("xlstm_125m").reduced()
    xcache = jax.eval_shape(lambda: zoo.make_cache(xcfg, 4, 32))
    xs = shd.cache_specs(xcache, mesh, xcfg)
    for spec in jax.tree.leaves(xs, is_leaf=lambda x: isinstance(x, P)):
        assert tuple(spec)[1] == "data"
