"""Training substrate: loop fault tolerance, gradual pruning, optimizer,
gradient compression, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import load_arch
from repro.data.pipeline import SyntheticLMData
from repro.launch.mesh import make_host_mesh
from repro.models import zoo
from repro.optim import (
    adafactor_init, adafactor_update, adamw_init, adamw_update,
    clip_by_global_norm, cosine_schedule, make_optimizer,
)
from repro.optim.compression import ef_topk_compress, ef_topk_init
from repro.train import gradual, loop, pruning, steps as tsteps


@pytest.fixture(scope="module")
def setup():
    cfg = load_arch("qwen2_0_5b").reduced(n_layers=2, d_model=64, n_heads=4,
                                          n_kv_heads=2, d_ff=128, vocab=128,
                                          head_dim=16)
    mesh = make_host_mesh()
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adamw")
    return cfg, mesh, params, opt


def make_step(cfg, mesh, microbatches=1):
    step_fn, _ = tsteps.make_train_step(
        cfg, mesh, lr_fn=cosine_schedule(1e-2, 5, 100), microbatches=microbatches
    )
    return jax.jit(step_fn)


def batches(cfg, n, b=4, s=32):
    data = SyntheticLMData(cfg.vocab, s, b, seed=1)
    return [
        {k: jnp.asarray(v) for k, v in data.batch(i).items()} for i in range(n)
    ]


def test_loss_decreases(setup):
    cfg, mesh, params, opt = setup
    jitted = make_step(cfg, mesh)
    masks = jax.tree.map(lambda x: None, params)
    opt_state = opt.init(params)
    losses = []
    for i, b in enumerate(batches(cfg, 30)):
        params, opt_state, m, _ = jitted(params, opt_state, masks, b, i, None)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses[:3] + losses[-3:]


def test_microbatched_grads_match(setup):
    cfg, mesh, params, opt = setup
    b = batches(cfg, 1, b=4)[0]
    opt_state = opt.init(params)
    masks = jax.tree.map(lambda x: None, params)
    p1, _, m1, _ = make_step(cfg, mesh, 1)(params, opt_state, masks, b, 0, None)
    p2, _, m2, _ = make_step(cfg, mesh, 2)(params, opt.init(params), masks, b, 0, None)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    d = max(float(jnp.abs(a - b_).max()) for a, b_ in
            zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3


def test_loop_checkpoint_resume_and_failures(setup, tmp_path):
    cfg, mesh, params, opt = setup
    jitted = make_step(cfg, mesh)
    masks = jax.tree.map(lambda x: None, params)
    bs = batches(cfg, 25)

    fails = {7}

    def injector(step):
        if step in fails:
            fails.discard(step)
            raise RuntimeError("injected transient failure")

    state = loop.LoopState(params=params, opt_state=opt.init(params), masks=masks)
    lcfg = loop.LoopConfig(total_steps=10, checkpoint_every=5,
                           checkpoint_dir=str(tmp_path), log_every=100)
    seen = []
    state = loop.run(state, jitted, iter(bs), lcfg,
                     on_step=lambda s, m: seen.append(s),
                     fail_injector=injector)
    assert state.step == 10
    assert len(seen) == 10

    # resume: a fresh loop picks up from the persisted step
    state2 = loop.LoopState(params=params, opt_state=opt.init(params), masks=masks)
    lcfg2 = loop.LoopConfig(total_steps=15, checkpoint_every=5,
                            checkpoint_dir=str(tmp_path), log_every=100)
    state2 = loop.run(state2, jitted, iter(bs), lcfg2)
    assert state2.step == 15


def test_gradual_schedule_ramp():
    cfg = load_arch("qwen2_0_5b").reduced()
    sched = gradual.GradualSchedule(target=cfg.hinm, vector_end_step=60, nm_step=80)
    assert sched.vector_sparsity(0) == 0.0
    assert abs(sched.vector_sparsity(60) - cfg.hinm.vector_sparsity) < 1e-9
    assert not sched.nm_active(79) and sched.nm_active(80)
    # monotone ramp
    vs = [sched.vector_sparsity(s) for s in range(0, 100, 5)]
    assert all(b >= a - 1e-9 for a, b in zip(vs, vs[1:]))


def test_gradual_masks_density(setup):
    cfg, mesh, params, _ = setup
    hcfg = cfg.hinm
    masks = gradual.recompute_masks(params, cfg, hcfg, nm_on=True)
    leaves = [m for m in jax.tree.leaves(masks) if m is not None]
    assert leaves
    dens = np.mean([float(np.asarray(m).mean()) for m in leaves])
    assert abs(dens - (1 - hcfg.total_sparsity)) < 0.02


def test_optimizers_step_shapes(setup):
    cfg, mesh, params, _ = setup
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    for init, update in ((adamw_init, adamw_update), (adafactor_init, adafactor_update)):
        st = init(params)
        new_p, new_st = update(grads, st, params, 1e-3)
        assert jax.tree.structure(new_p) == jax.tree.structure(params)
        d = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(new_p), jax.tree.leaves(params)))
        assert d > 0


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 10.0 * np.sqrt(10)) < 1e-3
    norm = float(jnp.linalg.norm(clipped["a"]))
    assert abs(norm - 1.0) < 1e-4


def test_ef_topk_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(100,)).astype(np.float32))}
    err = ef_topk_init(g)
    sent, err = ef_topk_compress(g, err, k_frac=0.1)
    nz = int((np.asarray(sent["w"]) != 0).sum())
    assert nz == 10
    # residual carries the unsent mass; next round re-sends it
    total = np.asarray(sent["w"]) + np.asarray(err["w"])
    np.testing.assert_allclose(total, np.asarray(g["w"]), rtol=1e-6)


def test_data_pipeline_determinism_and_sharding():
    d1 = SyntheticLMData(512, 16, 8, seed=3)
    d2 = SyntheticLMData(512, 16, 8, seed=3)
    b1, b2 = d1.batch(5), d2.batch(5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # different steps differ
    assert not np.array_equal(d1.batch(6)["tokens"], b1["tokens"])
