"""Mask-construction invariants (unit + hypothesis property tests)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparsity
from repro.core.types import HiNMConfig

from _hypothesis_compat import given, integers, sampled_from, settings


def cfg_v8():
    return HiNMConfig(v=8, n=2, m=4, vector_sparsity=0.5)


def test_nm_mask_exact_n_per_group(rng):
    x = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
    m = sparsity.nm_mask(jnp.abs(x), 2, 4)
    g = np.asarray(m).reshape(16, 8, 4)
    assert (g.sum(-1) == 2).all()


def test_nm_mask_keeps_largest(rng):
    x = jnp.asarray(np.array([[4.0, 3.0, 2.0, 1.0], [1.0, 2.0, 3.0, 4.0]]))
    m = np.asarray(sparsity.nm_mask(x, 2, 4))
    assert m.tolist() == [[True, True, False, False], [False, False, True, True]]


def test_vector_mask_column_counts(rng):
    cfg = cfg_v8()
    sal = jnp.asarray(rng.random((24, 20)).astype(np.float32))
    m = np.asarray(sparsity.vector_mask(sal, cfg))
    k = cfg.kept_columns(20)
    # per tile: exactly K columns fully kept, the rest fully dropped
    tiles = m.reshape(3, 8, 20)
    for t in tiles:
        col_any = t.any(axis=0)
        col_all = t.all(axis=0)
        assert (col_any == col_all).all()
        assert col_any.sum() == k


def test_hinm_mask_density(rng):
    cfg = cfg_v8()
    sal = jnp.asarray(rng.random((32, 32)).astype(np.float32))
    m = np.asarray(sparsity.hinm_mask(sal, cfg))
    assert abs(m.mean() - (1 - cfg.total_sparsity)) < 1e-6


def test_hinm_mask_from_columns_respects_order(rng):
    cfg = cfg_v8()
    sal = jnp.asarray(rng.random((8, 16)).astype(np.float32))
    ids = sparsity.kept_column_ids(sal, cfg)
    m1 = sparsity.hinm_mask_from_columns(sal, ids, cfg)
    # permuting columns within an M-group must not change the mask support
    perm = np.asarray(ids).copy()
    perm[:, [0, 1, 2, 3]] = perm[:, [3, 2, 1, 0]]
    m2 = sparsity.hinm_mask_from_columns(sal, jnp.asarray(perm), cfg)
    assert np.array_equal(np.asarray(m1), np.asarray(m2))


def test_unstructured_mask_density(rng):
    sal = jnp.asarray(rng.random((64, 64)).astype(np.float32))
    m = np.asarray(sparsity.unstructured_mask(sal, 0.75))
    assert abs(m.mean() - 0.25) < 0.01


@settings(max_examples=25, deadline=None)
@given(
    rows=sampled_from([8, 16, 24]),
    cols=sampled_from([8, 16, 32]),
    seed=integers(0, 1000),
    n=sampled_from([1, 2]),
)
def test_property_hinm_mask_invariants(rows, cols, seed, n):
    """For any saliency: per-tile kept-column count is K; kept columns carry
    exactly N survivors per M-group per row; dropped columns are all-zero."""
    cfg = HiNMConfig(v=8, n=n, m=4, vector_sparsity=0.5)
    sal = jnp.asarray(
        np.random.default_rng(seed).random((rows, cols)).astype(np.float32)
    )
    m = np.asarray(sparsity.hinm_mask(sal, cfg))
    k = cfg.kept_columns(cols)
    tiles = m.reshape(rows // 8, 8, cols)
    for t in tiles:
        kept_cols = t.any(axis=0)
        assert kept_cols.sum() <= k
        # every row keeps exactly K*N/M elements
        assert (t.sum(axis=1) == k * n // 4).all()


@settings(max_examples=25, deadline=None)
@given(seed=integers(0, 1000))
def test_property_retained_le_total(seed):
    cfg = cfg_v8()
    sal = jnp.asarray(np.random.default_rng(seed).random((16, 16)).astype(np.float32))
    r = float(sparsity.retained_saliency(sal, cfg))
    assert 0.0 <= r <= float(sal.sum()) + 1e-5


def test_config_validation():
    with pytest.raises(ValueError):
        HiNMConfig(v=7)
    with pytest.raises(ValueError):
        HiNMConfig(n=4, m=4)
    with pytest.raises(ValueError):
        HiNMConfig(vector_sparsity=1.0)
    assert abs(HiNMConfig().total_sparsity - 0.75) < 1e-9
