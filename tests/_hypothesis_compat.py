"""Optional-hypothesis shim for property tests.

`hypothesis` is a dev-only dependency; a missing install must not kill
test collection. When it is absent, `given` degrades to a deterministic
parametrize over a small fixed grid drawn from the declared strategies, so
the properties still get (reduced) coverage.
"""
from __future__ import annotations

import itertools

import pytest

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True

    def given(**strategies):
        return hypothesis.given(**strategies)

    def settings(**kw):
        return hypothesis.settings(**kw)

    def sampled_from(xs):
        return st.sampled_from(xs)

    def integers(lo, hi):
        return st.integers(lo, hi)

except ImportError:  # deterministic fallback
    HAVE_HYPOTHESIS = False

    class _Sampled:
        def __init__(self, xs):
            self.values = list(xs)

    def sampled_from(xs):
        return _Sampled(xs)

    def integers(lo, hi):
        # ends plus a fixed interior point: cheap boundary coverage
        vals = sorted({lo, (lo + hi) // 2, hi})
        return _Sampled(vals)

    def settings(**kw):
        def deco(fn):
            return fn

        return deco

    def given(**strategies):
        names = list(strategies)
        grids = [strategies[n].values for n in names]
        cases = list(itertools.product(*grids))

        if len(names) == 1:  # pytest expects scalars, not 1-tuples
            cases = [c[0] for c in cases]

        def deco(fn):
            argnames = ",".join(names)
            return pytest.mark.parametrize(argnames, cases)(fn)

        return deco
