"""End-to-end system behaviour: the paper's full pipeline on a small model.

prune (gyro) -> masked-dense finetune recovers loss -> pack -> serve,
with the gyro-permuted model beating the unpermuted one on retained
saliency (the objective the paper's accuracy gains are driven by).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import load_arch
from repro.data.pipeline import SyntheticLMData
from repro.launch.mesh import make_host_mesh
from repro.models import zoo
from repro.optim import cosine_schedule, make_optimizer
from repro.serve import ServeEngine
from repro.train import pruning, steps as tsteps


def test_full_pipeline_prune_finetune_serve():
    cfg = load_arch("qwen2_0_5b").reduced(n_layers=2, d_model=64, n_heads=4,
                                          n_kv_heads=2, d_ff=128, vocab=128,
                                          head_dim=16)
    mesh = make_host_mesh()
    data = SyntheticLMData(cfg.vocab, 32, 8, seed=0)
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adamw")

    # --- pretrain dense briefly
    step_fn, _ = tsteps.make_train_step(cfg, mesh, lr_fn=cosine_schedule(1e-2, 5, 200))
    jitted = jax.jit(step_fn)
    opt_state = opt.init(params)
    none_masks = jax.tree.map(lambda x: None, params)
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt_state, m, _ = jitted(params, opt_state, none_masks, batch, i, None)
    dense_loss = float(m["loss"])

    # --- one-shot HiNM prune: gyro vs noperm retained saliency
    p_gyro, masks_gyro, packed, rep_gyro = pruning.prune_model(
        params, cfg, method="gyro", ocp_iters=4, icp_iters=4)
    _, _, _, rep_noperm = pruning.prune_model(
        params, cfg, method="noperm", ocp_iters=1, icp_iters=1)
    assert rep_gyro.mean_retained >= rep_noperm.mean_retained

    # --- masked-dense finetune recovers loss
    opt_state = opt.init(p_gyro)
    pruned_params = p_gyro
    first = None
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in data.batch(100 + i).items()}
        pruned_params, opt_state, m, _ = jitted(
            pruned_params, opt_state, masks_gyro, batch, i, None)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first  # recovery in progress

    # --- repack the finetuned weights and serve
    pp, masks2, packed2, _ = pruning.prune_model(
        pruned_params, cfg, method="gyro", ocp_iters=2, icp_iters=2)
    eng = ServeEngine(cfg, packed2, max_seq=64)
    prompts = np.asarray(data.batch(999)["tokens"][:2, :8], np.int32)
    out, stats = eng.generate(prompts, max_new_tokens=4)
    assert out.shape == (2, 4)
    assert stats.weight_bytes_ratio < 1.0
