"""Collection shim: the conformance harness lives in `serve_conformance.py`
(importable by other test modules without the `test_` prefix, and runnable
as the sharded subprocess driver); re-export its tests here so default
pytest collection (`pytest -x -q`, the tier-1 command) runs them."""
from serve_conformance import *  # noqa: F401,F403
