"""Cross-family serving conformance suite: paged == stripe == isolated.

The serving contract is that no runtime optimisation may change tokens.
For every family x cache layout x (sharded / unsharded), a staggered
mixed-length workload driven through the continuous-batching `Scheduler`
must decode token-identically to isolated per-request batch-1 greedy
decode.  This module is the single reusable harness for that contract —
`test_serve.py`'s ad-hoc equivalence tests migrated here — plus the
sharded-pool churn property and the xlstm stripe-fallback regression.

Sharded cases need a multi-device jax.  The device count is locked at the
first jax import, so when this process has fewer than `N_DEVICES` devices
each sharded case re-runs this file as a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``; the CI
multi-device job sets that flag for the whole pytest process and the
cases run inline (no subprocess) on the fake 4-device host mesh.
"""
from __future__ import annotations

import functools
import os
import re
import subprocess
import sys

# subprocess entry: the fake multi-device host platform must be configured
# before jax initialises (harmless if the parent already exported it)
if __name__ == "__main__":
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

try:
    import pytest
except ImportError:  # `python tests/serve_conformance.py <mode>` driver
    pytest = None

from repro import compat
from repro.configs.base import load_arch
from repro.models import paging, zoo
from repro.serve import (ModelDrafter, Request, SamplingParams, Scheduler,
                         SlotKVCache, SpecConfig)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_DEVICES = 4

# families with a real paged layout; "ssm" (pure recurrent) is covered by
# the stripe-fallback regression instead
FAMILIES = ("transformer", "hybrid", "encdec")

_CASES = {
    # staggered arrivals + mixed prompt lengths + fewer slots than requests
    # exercise admission grouping, slot reuse, and page-gated admission
    "transformer": dict(arch="qwen2_0_5b",
                        reduced=dict(n_layers=2, d_model=64, n_heads=4,
                                     n_kv_heads=2, d_ff=128, vocab=128,
                                     head_dim=16),
                        packed=True, page=16, prompt_lens=(5, 8, 11, 8, 14),
                        max_new=7, seed=17),
    # 20 > window=16: the ring wraps inside its pages (roll-insert too)
    "hybrid": dict(arch="recurrentgemma_9b",
                   reduced=dict(window=16, n_layers=3),
                   page=8, prompt_lens=(8, 20, 12), max_new=6, seed=37),
    "encdec": dict(arch="seamless_m4t_medium", reduced={},
                   page=16, prompt_lens=(5, 9, 7), max_new=6, seed=37,
                   n_frames=6, cache_kw={"t_enc": 6}),
    "ssm": dict(arch="xlstm_125m",
                reduced=dict(n_layers=2, d_model=64, n_heads=4, vocab=128),
                page=16, prompt_lens=(5, 9, 7), max_new=6, seed=41),
}

MAX_SEQ = 64


def greedy_isolated(cfg, params, prompt, n, max_seq, eos=-1, embeds=None,
                    cache_kw=None):
    """Reference decode: raw batch-1 prefill + python token loop."""
    cache = zoo.make_cache(cfg, 1, max_seq, **(cache_kw or {}))
    emb = None if embeds is None else jnp.asarray(np.asarray(embeds)[None])
    last, cache = zoo.prefill(params, cfg, jnp.asarray(prompt[None]), cache,
                              embeds=emb)
    lg = zoo.logits_fn(params, cfg, last)[:, : cfg.vocab]
    toks = [int(jnp.argmax(lg, -1)[0])]
    while len(toks) < n and toks[-1] != eos:
        lg, cache = zoo.decode_step(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[:, : cfg.vocab], -1)[0]))
    return toks


@functools.lru_cache(maxsize=None)
def _model(family):
    c = _CASES[family]
    cfg = load_arch(c["arch"]).reduced(**c["reduced"])
    params = zoo.init(jax.random.PRNGKey(c["seed"]), cfg)
    if c.get("packed"):  # the HiNM serving path, not just dense decode
        from repro.train import pruning

        _, _, params, _ = pruning.prune_model(params, cfg, ocp_iters=2,
                                              icp_iters=2)
    return cfg, params


@functools.lru_cache(maxsize=None)
def _workload(family):
    c = _CASES[family]
    cfg, _ = _model(family)
    rng = np.random.default_rng(c["seed"])
    prompts = tuple(rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
                    for n in c["prompt_lens"])
    embeds = None
    if c.get("n_frames"):
        embeds = tuple(
            rng.standard_normal((c["n_frames"], cfg.d_model)).astype(np.float32)
            for _ in prompts)
    return prompts, embeds


def scheduler_tokens(family, layout, mesh=None, n_pages="auto",
                     max_slots=4, decode_chunk=4, spec=None):
    """Drive the family workload through a Scheduler; returns (tokens list
    per request, scheduler).  layout: "paged" | "stripe" ("stripe" is the
    PR 2 baseline: exact-length admission, per-slot max_seq stripes);
    spec: a SpecConfig for speculative draft/verify decode."""
    c = _CASES[family]
    cfg, params = _model(family)
    prompts, embeds = _workload(family)
    kw = dict(cache_kw=c.get("cache_kw"))
    if layout == "paged":
        kw.update(page=c["page"], n_pages=n_pages)
    else:
        kw.update(page=None, bucket=False)
    sched = Scheduler(cfg, params, max_slots=max_slots, max_seq=MAX_SEQ,
                      decode_chunk=decode_chunk, mesh=mesh, spec=spec,
                      flightrec=True, **kw)
    reqs = [Request(rid=i, prompt=p, params=SamplingParams(max_new_tokens=c["max_new"]),
                    embeds=None if embeds is None else embeds[i], arrival=i)
            for i, p in enumerate(prompts)]
    sched.run(reqs)
    return [r.tokens for r in reqs], sched


@functools.lru_cache(maxsize=None)
def isolated_tokens(family):
    c = _CASES[family]
    cfg, params = _model(family)
    prompts, embeds = _workload(family)
    return [greedy_isolated(cfg, params, p, c["max_new"], MAX_SEQ,
                            embeds=None if embeds is None else embeds[i],
                            cache_kw=c.get("cache_kw"))
            for i, p in enumerate(prompts)]


def _pool_leaf(cache):
    """The k pool leaf of the first paged attn stack in a cache pytree."""
    for node in jax.tree_util.tree_leaves(cache, is_leaf=paging.is_paged):
        if paging.is_paged(node):
            return node["k"]
    return None


def _mesh_size(mesh):
    return int(np.prod(list(mesh.shape.values()))) if mesh is not None else 1


# ---------------------------------------------------------------------------
# flight-record triage: a conformance failure is a determinism failure, so
# every scheduler here records its decision stream (serve/flightrec) and a
# token mismatch dumps the records plus the first diverging event instead
# of a bare token diff
# ---------------------------------------------------------------------------

TRIAGE_DIR = os.environ.get("REPRO_TRIAGE_DIR", os.path.join(REPO, "triage"))


def _fail_with_triage(label, msg, **scheds):
    """Dump each named scheduler's flight record to TRIAGE_DIR as JSONL;
    with two records, also write a rendered first-divergence report.  The
    paired runs differ in configuration by design (paged vs stripe, kernel
    vs gather), so the construction-time `config`/`dispatch` events are
    excluded from the diff — the first *workload* decision that diverged
    is the triage lead.  Raises AssertionError naming that event."""
    from repro.serve import diff_records

    os.makedirs(TRIAGE_DIR, exist_ok=True)
    safe = re.sub(r"[^A-Za-z0-9_.-]", "_", label)
    recs = {}
    for name, s in scheds.items():
        if s is not None and getattr(s, "flight", None) is not None:
            s.flight.dump(os.path.join(TRIAGE_DIR, f"{safe}.{name}.jsonl"))
            recs[name] = [e for e in s.flight.events
                          if e.kind not in ("config", "dispatch")]
    lines = [msg, f"flight records {sorted(recs)} -> {TRIAGE_DIR}"]
    if len(recs) >= 2:
        (na, a), (nb, b) = list(recs.items())[:2]
        rep = diff_records(a, b)
        path = os.path.join(TRIAGE_DIR, f"{safe}.diff.txt")
        with open(path, "w") as f:
            f.write(f"a = {na}, b = {nb} "
                    "(config/dispatch events excluded: the runs differ "
                    "there by design)\n" + rep.render() + "\n")
        if rep.first is not None:
            lines.append("first diverging event: " + rep.first.describe())
        lines.append(f"triage report: {path}")
    raise AssertionError("\n".join(lines))


def assert_conformance(family, mesh=None):
    """paged == stripe == isolated, on `mesh` (None = unsharded)."""
    iso = isolated_tokens(family)
    paged, sp = scheduler_tokens(family, "paged", mesh=mesh)
    stripe, ss = scheduler_tokens(family, "stripe", mesh=mesh)
    assert sp.kv.paged, f"{family}: paged layout did not engage"
    # bucketed admission engages exactly where it is sound: attention-only
    # prefill stacks bucket, recurrent blocks admit at exact length
    assert sp.bucket == zoo.supports_bucketed_prefill(sp.cfg)
    if paged != iso:
        _fail_with_triage(f"conformance_{family}_paged",
                          f"{family}: paged decode diverged from isolated",
                          paged=sp, stripe=ss)
    if stripe != iso:
        _fail_with_triage(f"conformance_{family}_stripe",
                          f"{family}: stripe decode diverged from isolated",
                          stripe=ss, paged=sp)
    # all pages drained back to the free list once the workload finishes
    assert sp.kv.n_free_pages == sp.kv.n_alloc_pages
    if mesh is not None:
        assert sp.kv.specs is not None and ss.kv.specs is not None
        if _mesh_size(mesh) > 1:
            # the pool must actually live page-sharded on the mesh, not
            # silently replicate (the equivalence would then prove nothing)
            pool_k = _pool_leaf(sp.kv.cache)
            assert not pool_k.sharding.is_fully_replicated, \
                f"{family}: page pool replicated on a {_mesh_size(mesh)}-device mesh"
    if family == "transformer":
        # page-constrained pool: admission waits on free pages, tokens
        # must still be identical (FIFO, no starvation)
        tight, st = scheduler_tokens(family, "paged", mesh=mesh, n_pages=6)
        assert tight == iso
        assert st.kv.n_free_pages == st.kv.n_alloc_pages


def assert_kernel_conformance(family, mesh=None, replicate=False):
    """The Pallas paged-attention kernel must be token-invisible.

    Runs the paged workload with ``knobs(paged_attn="interpret")`` — the
    kernel resolving KV tiles through the block table, under the Pallas
    interpreter so CPU CI executes the real kernel logic — and asserts
    token identity with the gather-path/isolated reference, for plain
    decode and for speculative verify (the s = k+1 multi-token branch).

    On a >1-device mesh the pool is page-sharded and the single-device
    kernel must auto-downgrade to the SPMD gather path (tokens still
    identical); with ``replicate=True`` (knob ``paged_attn_sharded``) the
    pools replicate instead and the kernel stays engaged under the mesh.
    """
    from repro.perf_knobs import knobs

    iso = isolated_tokens(family)
    kn = dict(paged_attn="interpret", paged_attn_sharded=replicate)
    with knobs(**kn):
        toks, sp = scheduler_tokens(family, "paged", mesh=mesh)
    assert sp.kv.paged, f"{family}: paged layout did not engage"
    if mesh is not None and _mesh_size(mesh) > 1 and not replicate:
        # page-sharded pool: the kernel cannot address a split pool, the
        # Scheduler must fall back to the gather path rather than crash
        assert sp.paged_attn == "off", sp.paged_attn
        assert sp.kv.page_sharded
    else:
        assert sp.paged_attn == "interpret", sp.paged_attn
        if replicate and mesh is not None and _mesh_size(mesh) > 1:
            # the knob replicated the pools (kernel-compatible layout);
            # this is the one sanctioned exception to the page-sharding
            # assertion in assert_conformance
            assert _pool_leaf(sp.kv.cache).sharding.is_fully_replicated
            assert not sp.kv.page_sharded
    if toks != iso:
        # pair the kernel record with a gather-path run of the SAME
        # workload: the streams match event-for-event up to the first
        # tile the kernel resolved differently
        _, ref = scheduler_tokens(family, "paged", mesh=mesh)
        _fail_with_triage(f"kernel_{family}",
                          f"{family}: kernel decode diverged from isolated",
                          kernel=sp, gather=ref)

    with knobs(**kn):
        stoks, ss = scheduler_tokens(family, "paged", mesh=mesh,
                                     spec=SpecConfig(k=3))
    if stoks != iso:
        _, ref = scheduler_tokens(family, "paged", mesh=mesh,
                                  spec=SpecConfig(k=3))
        _fail_with_triage(
            f"kernel_spec_{family}",
            f"{family}: kernel speculative decode diverged from isolated",
            kernel=ss, gather=ref)
    assert ss.stats.verify_steps > 0


def assert_spec_conformance(family, mesh=None):
    """Speculative greedy decode must be token-identical to non-speculative
    decode: the n-gram drafter guesses, the multi-token verify scores, and
    the commit/rollback keeps exactly the accepted prefix — on both cache
    layouts (and sharded pools when `mesh` is given), in BOTH the fused
    single-dispatch scan (default) and the unfused per-cycle dispatch
    chain (`SpecConfig.fused=False`), so fused == unfused == isolated."""
    iso = isolated_tokens(family)
    for layout in ("paged", "stripe"):
        toks, sp = scheduler_tokens(family, layout, mesh=mesh,
                                    spec=SpecConfig(k=3))
        assert toks == iso, \
            f"{family}/{layout}: speculative decode diverged from isolated"
        assert sp.stats.verify_steps > 0          # the spec path actually ran
        assert sp.stats.decode_tokens > 0
        # the fused scan really fused: one spec dispatch per decode step
        # covers all of that step's draft/verify cycles
        d = sp.telemetry.registry.counter("serve_spec_dispatches").value
        assert sp.spec.fused and d * sp._spec_cycles == sp.stats.verify_steps, \
            f"{family}/{layout}: {d} spec dispatches for " \
            f"{sp.stats.verify_steps} cycles — the scan did not fuse"
        if layout == "paged":
            assert sp.kv.paged
            # accept/reject churn must leave page accounting exact
            assert sp.kv.n_free_pages == sp.kv.n_alloc_pages
        if mesh is not None:
            assert sp.kv.specs is not None
        # unfused debugging fallback: token-identical by contract
        utoks, su = scheduler_tokens(family, layout, mesh=mesh,
                                     spec=SpecConfig(k=3, fused=False))
        assert utoks == iso, \
            f"{family}/{layout}: unfused spec decode diverged from isolated"
        assert su.stats.verify_steps > 0
        # per-request verify work is cadence-invariant: fused (many cycles
        # per dispatch) and unfused judge exactly the same draft tokens
        assert (su.stats.draft_proposed, su.stats.draft_accepted) == \
            (sp.stats.draft_proposed, sp.stats.draft_accepted)


def run_self_draft(family="transformer"):
    """A draft model identical to the target must have its every greedy
    proposal accepted: the strongest end-to-end pin of the draft-model
    path (draft prefill, K+1-step propose, lockstep cache rollback) —
    acceptance 1.0 and k+1 tokens per ridden verify, token-identically."""
    cfg, params = _model(family)
    iso = isolated_tokens(family)
    k = 3
    toks, sp = scheduler_tokens(family, "paged",
                                spec=SpecConfig(k=k, drafter=ModelDrafter(cfg, params)))
    assert toks == iso
    st = sp.stats
    assert st.acceptance_rate == 1.0, st.acceptance_rate
    assert st.draft_proposed > 0
    # every ridden verify emits its full k+1 tokens (max_new - 1 decode
    # tokens per request arrive in ceil((max_new - 1) / (k + 1)) verifies)
    for n_gen in (len(t) for t in toks):
        assert n_gen == _CASES[family]["max_new"]
    assert st.tokens_per_verify_step > 1.0
    # the whole draft pool drained alongside the target pool
    assert sp.draft_kv.n_free == sp.draft_kv.n_slots
    assert (sp.draft_kv.slot_len == 0).all()


# ---------------------------------------------------------------------------
# prefix sharing + chunked prefill: sharing must be token-invisible
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _share_workload(family):
    """Shared-prefix prompts: requests 0-2 share `2.5 * page` tokens and
    run past three full pages, so the divergent third page is indexed and
    later arrivals copy-on-write its shared head; 3-4 share exactly two
    full pages (pure full-page hits), all with distinct suffixes."""
    c = _CASES[family]
    cfg, _ = _model(family)
    page = c["page"]
    rng = np.random.default_rng(c["seed"] + 1)
    base = rng.integers(0, cfg.vocab, (2 * page + page // 2,)).astype(np.int32)
    prompts = []
    for i in range(5):
        # i < 3: base + a tail long enough that page 2 (base tail rows +
        # private suffix) is a FULL page -> registered -> CoW donor
        tail = rng.integers(0, cfg.vocab, (page // 2 + i,)).astype(np.int32)
        cut = len(base) if i < 3 else 2 * page
        prompts.append(np.concatenate([base[:cut], tail]))
    embeds = None
    if c.get("n_frames"):
        embeds = tuple(
            rng.standard_normal((c["n_frames"], cfg.d_model)).astype(np.float32)
            for _ in prompts)
    return tuple(prompts), embeds


def share_tokens(family, mesh=None, prefix_share="auto", prefill_chunk=None,
                 spec=None, async_admission="auto"):
    """Drive the shared-prefix workload; returns (tokens, scheduler)."""
    c = _CASES[family]
    cfg, params = _model(family)
    prompts, embeds = _share_workload(family)
    sched = Scheduler(cfg, params, max_slots=4, max_seq=MAX_SEQ,
                      decode_chunk=4, mesh=mesh, spec=spec, page=c["page"],
                      n_pages="auto", cache_kw=c.get("cache_kw"),
                      prefix_share=prefix_share, prefill_chunk=prefill_chunk,
                      async_admission=async_admission, flightrec=True)
    reqs = [Request(rid=i, prompt=p,
                    params=SamplingParams(max_new_tokens=c["max_new"]),
                    embeds=None if embeds is None else embeds[i], arrival=i)
            for i, p in enumerate(prompts)]
    sched.run(reqs)
    return [r.tokens for r in reqs], sched


@functools.lru_cache(maxsize=None)
def isolated_share_tokens(family):
    c = _CASES[family]
    cfg, params = _model(family)
    prompts, embeds = _share_workload(family)
    return [greedy_isolated(cfg, params, p, c["max_new"], MAX_SEQ,
                            embeds=None if embeds is None else embeds[i],
                            cache_kw=c.get("cache_kw"))
            for i, p in enumerate(prompts)]


def assert_share_conformance(family, mesh=None):
    """Prefix sharing, CoW and chunked prefill must not change one token:
    shared == unshared == isolated, with exact refcount accounting and a
    pool that drains to pristine once the index is dropped.  Families
    without bitwise-sharable K/V rows must downgrade "auto" silently."""
    iso = isolated_share_tokens(family)
    off, s_off = share_tokens(family, mesh=mesh, prefix_share=False)
    assert off == iso, f"{family}: sharing-off run diverged from isolated"
    on, sp = share_tokens(family, mesh=mesh)
    if on != iso:
        _fail_with_triage(f"share_{family}",
                          f"{family}: prefix sharing changed tokens",
                          shared=sp, unshared=s_off)
    if not zoo.supports_prefix_share(sp.cfg):
        assert sp.prefix is None  # "auto" downgraded silently
        return
    assert sp.prefix is not None
    kv, st = sp.kv, sp.stats
    # the sharing machinery actually engaged: full-page hits, a divergent
    # tail copy, and a hit rate the workload design guarantees
    assert st.prefix_hit_tokens > 0
    assert st.prefix_hit_rate > 0
    assert kv.cow_copies > 0, "divergent tails never exercised CoW"
    # refcount conservation, then pristine once retention is dropped
    assert kv.n_free_pages + kv.n_referenced_pages == kv.n_alloc_pages
    sp.clear_prefix_cache()
    assert kv.n_free_pages == kv.n_alloc_pages
    kpos = np.asarray(kv.cache["kpos"])
    assert (kpos[:, paging.N_RESERVED:] == paging.KPOS_SENTINEL).all(), \
        "a drained pool kept real kpos rows (missed last-reference sweep)"

    # chunked prefill interleaved with decode: still token-identical
    ch, sc = share_tokens(family, mesh=mesh, prefill_chunk=_CASES[family]["page"])
    assert ch == iso, f"{family}: chunked prefill changed tokens"
    assert sc.stats.prefill_chunks > 0

    # speculative decode over shared pages + chunked admission
    sk, ss = share_tokens(family, mesh=mesh, spec=SpecConfig(k=3),
                          prefill_chunk=_CASES[family]["page"])
    assert sk == iso, f"{family}: spec decode over shared pages diverged"
    assert ss.stats.verify_steps > 0
    assert ss.stats.prefix_hit_tokens > 0


def assert_spec_share_conformance(family, mesh=None):
    """Speculation composed with the admission machinery — the two pins:

    (1) spec x chunked prefill x prefix sharing decodes token-identically
    to isolated, in BOTH the fused scan and the unfused dispatch chain,
    and under synchronous admission — mid-prefill lanes are excluded from
    draft/verify (`spec.acceptance` zeroes cnt AND judged for inactive
    lanes), so a slot still in extension prefill never gets verify rows
    written or junk folded into its acceptance stats;
    (2) prefix-shared admission must not starve the n-gram drafter: the
    history corpus seeds from the COMPLETE prompt (`spec.seed_history`),
    including rows served by page mapping rather than prefill, so
    acceptance under sharing matches the unshared run exactly."""
    iso = isolated_share_tokens(family)
    page = _CASES[family]["page"]
    off, s_off = share_tokens(family, mesh=mesh, prefix_share=False,
                              spec=SpecConfig(k=3))
    assert off == iso, f"{family}: spec sharing-off run diverged"
    on, s_on = share_tokens(family, mesh=mesh, spec=SpecConfig(k=3))
    assert on == iso, f"{family}: spec over shared pages changed tokens"
    if not zoo.supports_prefix_share(s_on.cfg):
        assert s_on.prefix is None  # "auto" downgraded silently
        return
    assert s_on.stats.prefix_hit_tokens > 0
    # pin (2): per-slot draft/verify work is admission-invariant, so the
    # aggregate (proposed, accepted) pair must match EXACTLY — a drafter
    # whose history misses the page-mapped prompt rows fails here first
    assert (s_on.stats.draft_proposed, s_on.stats.draft_accepted) == \
        (s_off.stats.draft_proposed, s_off.stats.draft_accepted), \
        f"{family}: sharing changed acceptance " \
        f"({s_on.stats.acceptance_rate:.3f} vs {s_off.stats.acceptance_rate:.3f})"
    assert s_on.stats.draft_accepted > 0, \
        f"{family}: acceptance collapsed under prefix sharing"
    # pin (1): chunked prefill interleaves mid-prefill lanes with live
    # spec decode — fused, unfused, and synchronous admission
    for kw in (dict(spec=SpecConfig(k=3)),
               dict(spec=SpecConfig(k=3, fused=False)),
               dict(spec=SpecConfig(k=3), async_admission=False)):
        ch, sc = share_tokens(family, mesh=mesh, prefill_chunk=page, **kw)
        assert ch == iso, \
            f"{family}: spec x chunked x shared diverged ({kw})"
        assert sc.stats.prefill_chunks > 0
        assert sc.stats.verify_steps > 0
        assert (sc.stats.draft_proposed, sc.stats.draft_accepted) == \
            (s_off.stats.draft_proposed, s_off.stats.draft_accepted), \
            f"{family}: chunked/shared admission changed acceptance ({kw})"


# ---------------------------------------------------------------------------
# churn property: random admit/release against the (sharded) paged pool
# ---------------------------------------------------------------------------


def run_churn(seed, mesh=None, n_ops=40):
    """Random admit/share/rollback/release churn against a paged
    SlotKVCache: refcount accounting must match an independent host model
    at every step (conservation law: a page is on a free list exactly when
    its modelled refcount is zero), speculative rollbacks (random
    accept/reject prefixes over a slot's trailing rows) must keep
    byte/page/slot_len accounting untouched and sweep the rejected rows
    exactly, shared admits (`map_slot` onto a live donor's full pages,
    with random copy-on-write tails) must be row-exact for both owners
    through any release order, no page may leak rows after drain, and
    pool bytes never move (the pool never reallocates)."""
    cfg, _ = _model("transformer")
    # n_pages=10 -> 12 with the reserved pair: already divides a 4-way mesh,
    # so sharded and unsharded pools are byte-identical
    kv = SlotKVCache(cfg, 4, MAX_SEQ, page=8, n_pages=10, mesh=mesh)
    assert kv.paged and kv.n_pages == 12
    bytes0 = kv.pool_bytes()
    tpl = kv.template(1)
    ar = jnp.arange(MAX_SEQ, dtype=jnp.int32)
    rng = np.random.default_rng(seed)
    # slot -> [current rows, reserved rows, floor]: `floor` is the lowest
    # row a rollback may rewind to — rows below it live in pages another
    # owner maps (mapped-in shared pages, or full pages donated away), the
    # analogue of the scheduler never rolling back into prompt rows
    live: dict[int, list[int]] = {}
    model_ref: dict[int, int] = {}  # page -> expected refcount

    def check():
        for p in range(paging.N_RESERVED, kv.n_pages):
            assert kv.page_ref(p) == model_ref.get(p, 0), \
                f"page {p}: ref {kv.page_ref(p)} != model {model_ref.get(p, 0)}"
        n_ref = sum(1 for v in model_ref.values() if v > 0)
        assert kv.n_free_pages == kv.n_alloc_pages - n_ref, \
            f"free-list drift: {kv.n_free_pages} free, {n_ref} referenced"
        assert kv.n_referenced_pages == n_ref
        assert kv.pool_bytes() == bytes0  # the pool never reallocates

    def slot_rows_on_device(slot):
        """Real (non-sentinel) kpos rows of `slot`, via its block table."""
        kpos = np.asarray(kv.cache["kpos"])[0]
        bt = np.asarray(kv.cache["bt"])[0, slot]
        alloc = int(np.asarray(kv.cache["alloc"])[0, slot])
        rows = [kpos[bt[p // kv.page], p % kv.page]
                for p in range(alloc * kv.page)]
        return [i for i, r in enumerate(rows) if r != paging.KPOS_SENTINEL]

    for _ in range(n_ops):
        roll = rng.random()
        can_roll = [s for s in sorted(live) if live[s][0] - live[s][2] >= 1]
        donors = [s for s in sorted(live) if live[s][0] >= kv.page]
        if can_roll and roll < 0.25:
            # speculative commit/rollback: treat the slot's last n_spec
            # rows as verify-written candidates and keep a random prefix
            # (never rewinding below the slot's sharing floor)
            slot = int(rng.choice(can_roll))
            rows_now, _, floor = live[slot]
            n_spec = int(rng.integers(1, min(rows_now - floor, 6) + 1))
            keep_n = int(rng.integers(0, n_spec + 1))
            pos0 = np.zeros((kv.n_slots,), np.int32)
            keep = np.zeros((kv.n_slots,), np.int32)
            for s, (r, _, _) in live.items():  # untouched slots: empty window
                pos0[s] = r
            pos0[slot], keep[slot] = rows_now - n_spec, keep_n
            free_before = kv.n_free_pages
            kv.rollback(pos0, keep, n_spec)
            live[slot][0] = rows_now - n_spec + keep_n
            kv.slot_len[slot] = live[slot][0]
            # rollback moves no pages and reallocates nothing
            assert kv.n_free_pages == free_before
            assert kv.slot_capacity(slot) == live[slot][1]
            # the device pos counter rewound with the sweep
            assert int(np.asarray(kv.cache["pos"])[0, slot]) == live[slot][0]
        elif donors and kv.n_free > 0 and 0.25 <= roll < 0.45:
            # shared admit: map a new slot onto a random prefix of a live
            # donor's full pages, optionally CoW-ing a divergent tail out
            # of the donor's next page
            donor = int(rng.choice(donors))
            d_rows = live[donor][0]
            d_pages = kv.slot_pages(donor)
            n_share = int(rng.integers(1, d_rows // kv.page + 1))
            shared = d_pages[:n_share]
            shared_rows = n_share * kv.page
            cow_src, cow_rows = None, 0
            rem = d_rows - shared_rows
            if rem > 0 and rng.random() < 0.5:
                cow_src = d_pages[n_share]
                cow_rows = int(rng.integers(1, rem + 1))
            mapped = shared_rows + cow_rows
            reserve = min(MAX_SEQ, mapped + int(rng.integers(1, 16)))
            n_fresh = kv.pages_needed(reserve) - n_share
            if n_fresh < 1 or n_fresh > kv.n_free_pages:
                check()  # a refused mapping must not move accounting
                continue
            slot = kv.acquire()
            pages = kv.map_slot(slot, shared, shared_rows, reserve,
                                cow_src=cow_src, cow_rows=cow_rows)
            assert pages[:n_share] == shared  # prefix order preserved
            for p in pages:  # shared ref++, fresh 0 -> 1
                model_ref[p] = model_ref.get(p, 0) + 1
            # the donor's donated full pages may never be rolled back
            # (the sharer is attending to them); the CoW source page
            # stays donor-private — the sharer holds a copy
            live[donor][2] = max(live[donor][2], shared_rows)
            live[slot] = [mapped, reserve, mapped]
            assert kv.slot_len[slot] == mapped
            assert kv.slot_capacity(slot) == reserve
        elif kv.n_free > 0 and (not live or roll < 0.65):
            rows = int(rng.integers(1, 33))
            reserve = min(MAX_SEQ, rows + int(rng.integers(0, 16)))
            if not kv.can_admit(reserve):
                check()  # a refused admission must not move accounting
                continue
            slot = kv.acquire()
            # a stripe carrying `rows` real kpos rows, so live pages hold
            # real positions and the leak check below is meaningful
            stripe = dict(
                tpl,
                kpos=jnp.where(ar[None, None, :] < rows, ar[None, None, :],
                               paging.KPOS_SENTINEL),
                pos=jnp.full_like(tpl["pos"], rows))
            kv.insert(slot, stripe, rows, reserve=reserve)
            live[slot] = [rows, reserve, 0]
            for p in kv.slot_pages(slot):
                assert model_ref.get(p, 0) == 0  # fresh pages only
                model_ref[p] = 1
            assert kv.slot_len[slot] == rows
            assert kv.slot_capacity(slot) == reserve
        elif live:
            slot = int(rng.choice(sorted(live)))
            pages = kv.slot_pages(slot)
            kv.release(slot)
            live.pop(slot)
            for p in pages:
                model_ref[p] -= 1
            assert kv.slot_len[slot] == 0 and kv.slot_capacity(slot) == 0
        check()

    # before draining: every live slot holds exactly its tracked rows —
    # rollbacks swept the rejected suffixes and nothing else, and a
    # released co-owner's pages were NOT swept under the survivors
    for slot, (rows_now, _, _) in live.items():
        assert slot_rows_on_device(slot) == list(range(rows_now)), \
            f"slot {slot}: device rows diverged after rollback/share churn"
    for slot in sorted(live):
        for p in kv.slot_pages(slot):
            model_ref[p] -= 1
        kv.release(slot)
    assert all(v == 0 for v in model_ref.values()), "refcounts leaked"
    assert kv.n_free_pages == kv.n_alloc_pages, "leaked pages after drain"
    assert kv.n_free == kv.n_slots
    assert (kv.slot_len == 0).all()
    kpos = np.asarray(kv.cache["kpos"])
    assert (kpos[:, paging.N_RESERVED:] == paging.KPOS_SENTINEL).all(), \
        "a freed page kept real kpos rows (would leak into a recycled slot)"
    assert (kpos[:, paging.SENTINEL_PAGE] == paging.KPOS_SENTINEL).all()


# ---------------------------------------------------------------------------
# xlstm: pure recurrent families fall back to stripes under a mesh
# ---------------------------------------------------------------------------


def run_xlstm_fallback(mesh):
    """Requesting a paged pool on a pure-recurrent family must fall back to
    stripes transparently — including under a sharded mesh, where
    cache_specs must resolve the recurrent state tree (no attention
    leaves) instead of crashing — and decode token-identically."""
    toks, sched = scheduler_tokens("ssm", "paged", mesh=mesh)
    assert not sched.kv.paged  # transparent stripe fallback
    if mesh is not None:
        assert sched.kv.specs is not None  # cache_specs resolved the tree
    assert toks == isolated_tokens("ssm")


# ---------------------------------------------------------------------------
# pytest entry points
# ---------------------------------------------------------------------------


def _sharded_case(mode: str) -> None:
    """Run `mode` on a 4-device mesh: inline when this process already has
    enough devices (CI multi-device job), else in a subprocess with the
    host-platform device-count flag."""
    if len(jax.devices()) >= N_DEVICES:
        _drive(mode, compat.make_mesh((N_DEVICES,), ("data",)))
        return
    # merge with inherited flags, but override any smaller device count
    # (we only reach here when this process has < N_DEVICES devices)
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   os.environ.get("XLA_FLAGS", ""))
    flags = (flags + " --xla_force_host_platform_device_count=4").strip()
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""),
               XLA_FLAGS=flags)
    out = subprocess.run([sys.executable, os.path.abspath(__file__), mode],
                         env=env, capture_output=True, text=True, timeout=600)
    assert f"CONFORMANCE_OK {mode}" in out.stdout, out.stdout + out.stderr


def _drive(mode: str, mesh) -> None:
    if mode.startswith("conformance:"):
        assert_conformance(mode.split(":", 1)[1], mesh=mesh)
    elif mode.startswith("kernel:"):
        assert_kernel_conformance(mode.split(":", 1)[1], mesh=mesh)
    elif mode.startswith("kernelrepl:"):
        assert_kernel_conformance(mode.split(":", 1)[1], mesh=mesh,
                                  replicate=True)
    elif mode.startswith("spec:"):
        assert_spec_conformance(mode.split(":", 1)[1], mesh=mesh)
    elif mode.startswith("share:"):
        assert_share_conformance(mode.split(":", 1)[1], mesh=mesh)
    elif mode.startswith("specshare:"):
        assert_spec_share_conformance(mode.split(":", 1)[1], mesh=mesh)
    elif mode == "churn":
        for seed in (0, 1, 2):
            run_churn(seed, mesh=mesh)
    elif mode == "xlstm":
        run_xlstm_fallback(mesh)
    else:
        raise ValueError(mode)


if pytest is not None:

    @pytest.mark.parametrize("family", FAMILIES + ("ssm",))
    def test_conformance_unsharded(family):
        if family == "ssm":
            run_xlstm_fallback(None)  # fallback is the ssm conformance case
        else:
            assert_conformance(family, mesh=None)

    @pytest.mark.parametrize("family", FAMILIES)
    def test_conformance_sharded(family):
        _sharded_case(f"conformance:{family}")

    @pytest.mark.parametrize("family", FAMILIES)
    def test_conformance_kernel_unsharded(family):
        assert_kernel_conformance(family, mesh=None)

    def test_conformance_kernel_sharded_downgrade():
        # page-sharded pool on a real 4-device mesh: kernel -> gather
        _sharded_case("kernel:transformer")

    def test_conformance_kernel_sharded_replicated():
        # paged_attn_sharded knob: replicated pools, kernel under the mesh
        _sharded_case("kernelrepl:transformer")

    @pytest.mark.parametrize("family", FAMILIES)
    def test_spec_conformance_unsharded(family):
        assert_spec_conformance(family, mesh=None)

    @pytest.mark.parametrize("family", FAMILIES)
    def test_spec_conformance_sharded(family):
        _sharded_case(f"spec:{family}")

    def test_spec_self_draft_model():
        run_self_draft("transformer")

    @pytest.mark.parametrize("family", FAMILIES)
    def test_share_conformance_unsharded(family):
        assert_share_conformance(family, mesh=None)

    def test_share_conformance_sharded():
        # prefix sharing + CoW + chunked prefill on a page-sharded pool
        _sharded_case("share:transformer")

    @pytest.mark.parametrize("family", FAMILIES)
    def test_spec_share_conformance_unsharded(family):
        assert_spec_share_conformance(family, mesh=None)

    def test_spec_share_conformance_sharded():
        # spec x chunked prefill x prefix sharing on a page-sharded pool
        _sharded_case("specshare:transformer")

    def test_spec_unsupported_family():
        cfg, params = _model("ssm")
        with pytest.raises(ValueError, match="no\\s+speculative"):
            Scheduler(cfg, params, max_slots=2, max_seq=MAX_SEQ,
                      spec=SpecConfig(k=2))

    from _hypothesis_compat import given, integers, settings

    @settings(max_examples=6, deadline=None)
    @given(seed=integers(0, 100))
    def test_churn_property(seed):
        run_churn(seed, mesh=None)
        # 1-device mesh: the sharded code path (specs, device_put,
        # constrained writes) without multi-device execution
        run_churn(seed, mesh=compat.make_mesh((1,), ("data",)))

    def test_churn_sharded():
        _sharded_case("churn")

    def test_xlstm_stripe_fallback_sharded():
        _sharded_case("xlstm")


if __name__ == "__main__":
    _mode = sys.argv[1] if len(sys.argv) > 1 else "conformance:transformer"
    assert len(jax.devices()) >= N_DEVICES, \
        f"{len(jax.devices())} devices; the driver needs the XLA flag"
    _drive(_mode, compat.make_mesh((N_DEVICES,), ("data",)))
    print(f"CONFORMANCE_OK {_mode}")
