"""Paged-attention decode kernel + packed-serving unit tests.

The kernel (`kernels/paged_attn`) resolves KV tiles straight through the
block table inside the Pallas grid; these tests pin it against the jnp
reference — `pool[bt]` gather + `layers._attn_chunked` — across the
serving geometries (GQA/MHA, windowed rings, sentinel pages, rollback-
swept rows, multi-token spec verify, bf16 pools), all under
``backend="interpret"`` so CPU CI executes the real kernel logic.
End-to-end token identity through the Scheduler lives in
`serve_conformance.py` (kernel on and off); this file covers the kernel
contract itself plus the packed-params hooks (`zoo.pack_params` /
`zoo.unpack_params`) and the serving-mode resolution knob.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import load_arch
from repro.core import packing
from repro.core.types import HiNMConfig, PackedHiNM
from repro.kernels import ops
from repro.kernels.paged_attn import pick_pp
from repro.models import layers, paging, zoo
from repro.models import module as nn

RNG = np.random.default_rng(0)


def _paged_case(b, s, kvh, g, hd, page, n_bt, n_pages, window, dtype,
                sweep=2, seed=0):
    """Build a randomly allocated paged pool + block tables.

    Every slot gets a random page allocation and a random live row count;
    `sweep` interior rows are reset to the kpos sentinel (exactly what a
    speculative rollback leaves behind), unallocated bt entries point at
    the sentinel page, and q sits at the slot's next `s` positions.
    """
    rng = np.random.default_rng(seed)
    h = kvh * g
    pool_shape = (n_pages, page, kvh, hd)
    kp = jnp.asarray(rng.normal(size=pool_shape), dtype)
    vp = jnp.asarray(rng.normal(size=pool_shape), dtype)
    kpos = np.full((n_pages, page), paging.KPOS_SENTINEL, np.int32)
    bt = np.full((b, n_bt), paging.SENTINEL_PAGE, np.int32)
    free = list(range(paging.N_RESERVED, n_pages))
    rng.shuffle(free)
    positions = []
    for bi in range(b):
        n_alloc = int(rng.integers(1, n_bt + 1))
        pages = [free.pop() for _ in range(n_alloc)]
        bt[bi, :n_alloc] = pages
        live = int(rng.integers(1, n_alloc * page + 1))
        for r in range(live):
            kpos[pages[r // page], r % page] = r
        for r in rng.choice(live, size=min(sweep, live), replace=False):
            if r != live - 1:  # keep the newest row: q attends to itself
                kpos[pages[r // page], r % page] = paging.KPOS_SENTINEL
        positions.append([live - 1 + i for i in range(s)])
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
    return (q, kp, vp, jnp.asarray(kpos), jnp.asarray(bt),
            jnp.asarray(positions, jnp.int32))


def _gather_ref(q, kp, vp, kpos, bt, q_pos, window):
    k_view = paging.gather_view(kp, bt)
    v_view = paging.gather_view(vp, bt)
    p_view = paging.gather_view(kpos, bt)
    return layers._attn_chunked(q, k_view, v_view, q_pos, p_view,
                                True, window, 1024)


CASES = [
    # b  s kvh g  hd page n_bt n_pages window dtype        tol
    (3, 1, 2, 2, 32, 8, 4, 16, 0, jnp.float32, 5e-6),   # GQA decode
    (2, 1, 4, 1, 16, 4, 8, 40, 0, jnp.float32, 5e-6),   # MHA, many pages
    (3, 1, 2, 2, 32, 8, 4, 16, 16, jnp.float32, 5e-6),  # sliding window
    (2, 3, 2, 2, 32, 8, 4, 16, 0, jnp.float32, 5e-6),   # spec verify s=3
    (2, 4, 2, 2, 16, 16, 2, 8, 0, jnp.float32, 5e-6),   # s=4, page=16
    (3, 1, 2, 4, 64, 16, 4, 16, 0, jnp.bfloat16, 5e-2),  # bf16 pool
    (1, 1, 2, 2, 32, 8, 1, 4, 0, jnp.float32, 5e-6),    # single page
]


@pytest.mark.parametrize(
    "b,s,kvh,g,hd,page,n_bt,n_pages,window,dtype,tol", CASES)
def test_kernel_matches_gather(b, s, kvh, g, hd, page, n_bt, n_pages,
                               window, dtype, tol):
    q, kp, vp, kpos, bt, q_pos = _paged_case(
        b, s, kvh, g, hd, page, n_bt, n_pages, window, dtype)
    out = ops.paged_attention(q, kp, vp, kpos, bt, q_pos, window=window,
                              backend="interpret")
    ref = _gather_ref(q, kp, vp, kpos, bt, q_pos, window)
    assert out.dtype == q.dtype
    err = float(jnp.abs(out.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    assert err < tol, err


def test_kernel_sentinel_heavy():
    """A slot whose allocation is almost entirely sentinel/swept rows:
    only the newest row survives, so attention must reduce to exactly
    that row's V — every other lane masks through the kpos sentinel."""
    q, kp, vp, kpos, bt, q_pos = _paged_case(
        2, 1, 2, 2, 32, 8, 4, 16, 0, jnp.float32, seed=3)
    kpos_np = np.asarray(kpos).copy()
    bt_np = np.asarray(bt)
    for bi in range(2):
        newest = int(q_pos[bi, 0])
        for r in range(newest):
            pg = bt_np[bi, r // 8]
            kpos_np[pg, r % 8] = paging.KPOS_SENTINEL
    kpos = jnp.asarray(kpos_np)
    out = ops.paged_attention(q, kp, vp, kpos, bt, q_pos, backend="interpret")
    ref = _gather_ref(q, kp, vp, kpos, bt, q_pos, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-6)
    for bi in range(2):
        newest = int(q_pos[bi, 0])
        pg, off = bt_np[bi, newest // 8], newest % 8
        want = np.asarray(vp)[pg, off]                      # (KV, hd)
        got = np.asarray(out)[bi, 0].reshape(2, 2, 32)      # (KV, G, hd)
        np.testing.assert_allclose(got, np.broadcast_to(want[:, None],
                                                        got.shape), atol=5e-6)


def test_kernel_backend_dispatch():
    q, kp, vp, kpos, bt, q_pos = _paged_case(
        2, 1, 2, 2, 32, 8, 4, 16, 0, jnp.float32)
    # gather/off defer to the jnp path by returning None
    assert ops.paged_attention(q, kp, vp, kpos, bt, q_pos,
                               backend="off") is None
    assert ops.paged_attention(q, kp, vp, kpos, bt, q_pos,
                               backend="gather") is None
    # auto off-TPU defers too (CPU CI)
    if jax.devices()[0].platform != "tpu":
        assert ops.paged_attention(q, kp, vp, kpos, bt, q_pos,
                                   backend="auto") is None
    with pytest.raises(ValueError, match="paged-attention backend"):
        ops.paged_attention(q, kp, vp, kpos, bt, q_pos, backend="nope")


# ---------------------------------------------------------------------------
# VMEM tile picking
# ---------------------------------------------------------------------------


def test_pick_tile():
    # fits whole -> whole; halves until under budget; divisibility holds
    assert ops.pick_tile(8, 0, 100, budget=1000) == 8
    assert ops.pick_tile(8, 0, 300, budget=1000) == 2
    assert ops.pick_tile(12, 0, 100, budget=500, divide=True) == 3
    # fixed cost alone over budget -> floor (never 0)
    assert ops.pick_tile(8, 2000, 100, budget=1000) == 1
    assert ops.pick_tile(8, 2000, 100, budget=1000, floor=4) == 4
    # start caps the initial tile
    assert ops.pick_tile(64, 0, 1, budget=1 << 30, start=8) == 8


def test_pick_pp_within_budget():
    for n_bt, page, hd, gs in [(4, 16, 32, 8), (32, 64, 128, 16),
                               (128, 256, 128, 8)]:
        pp = pick_pp(n_bt, page, hd, gs, 2)
        assert 1 <= pp <= min(8, n_bt) and n_bt % pp == 0
        per_page = page * hd * (2 + 4) * 2 + page * 4 + gs * page * 4 * 3
        fixed = gs * hd * 4 * 3 + gs * 128 * 4 * 2 + gs * 4
        assert pp == 1 or fixed + per_page * pp <= ops.VMEM_BUDGET_BYTES


# ---------------------------------------------------------------------------
# paged-branch write contract (layers.attention)
# ---------------------------------------------------------------------------


def _mini_attn_setup(window=0):
    cfg = load_arch("qwen2_0_5b").reduced(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
        vocab=64, head_dim=16, window=window)
    ks = nn.split_keys(jax.random.PRNGKey(0), 4)
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    params = {"wq": nn.dense_init(ks[0], d, h * hd, cfg.dtype),
              "wk": nn.dense_init(ks[1], d, cfg.n_kv_heads * hd, cfg.dtype),
              "wv": nn.dense_init(ks[2], d, cfg.n_kv_heads * hd, cfg.dtype),
              "wo": nn.dense_init(ks[3], h * hd, d, cfg.dtype)}
    page, n_pages = 4, 8
    cache = {
        "k": jnp.zeros((n_pages, page, cfg.n_kv_heads, hd), cfg.dtype),
        "v": jnp.zeros((n_pages, page, cfg.n_kv_heads, hd), cfg.dtype),
        "kpos": jnp.full((n_pages, page), paging.KPOS_SENTINEL, jnp.int32),
        "bt": jnp.asarray([[2, 3]], jnp.int32),
        "alloc": jnp.asarray([2], jnp.int32),
        "pos": jnp.asarray([1], jnp.int32),
    }
    return cfg, params, cache


def test_paged_multitoken_requires_spec():
    """s > 1 against a paged cache is only legal on the speculative-verify
    branch (zoo.verify_step passes spec=True); the error must say where
    multi-token writes actually go, so the message is pinned here."""
    cfg, params, cache = _mini_attn_setup()
    x = jnp.zeros((1, 2, cfg.d_model), cfg.dtype)
    positions = jnp.asarray([[1, 2]], jnp.int32)
    with pytest.raises(ValueError, match=r"speculative verify[\s\S]*"
                                         r"zoo\.verify_step passes spec=True"):
        layers.attention(params, x, positions, cfg, cache=cache)
    # the same call IS legal as a spec-verify write
    out, new_cache = layers.attention(params, x, positions, cfg,
                                      cache=cache, spec=True)
    assert out.shape == (1, 2, cfg.d_model)
    assert int(new_cache["pos"][0]) == 3


def test_paged_spec_write_rejects_windowed_ring():
    cfg, params, cache = _mini_attn_setup(window=8)
    x = jnp.zeros((1, 2, cfg.d_model), cfg.dtype)
    positions = jnp.asarray([[1, 2]], jnp.int32)
    with pytest.raises(ValueError, match="windowed ring"):
        layers.attention(params, x, positions, cfg, cache=cache, spec=True)


# ---------------------------------------------------------------------------
# packed-serving params hooks
# ---------------------------------------------------------------------------


def _packed_model():
    from repro.train import pruning

    cfg = load_arch("qwen2_0_5b").reduced(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=256, head_dim=32)
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    _, _, packed, _ = pruning.prune_model(params, cfg, ocp_iters=1,
                                          icp_iters=1)
    return cfg, params, packed


def _packed_leaves(tree):
    return [l for l in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, PackedHiNM))
        if isinstance(l, PackedHiNM)]


def test_pack_unpack_params_hooks():
    cfg, dense, packed = _packed_model()
    n0 = len(_packed_leaves(packed))
    assert n0 > 0

    # pack_params on dense params packs every planned projection
    pk = zoo.pack_params(cfg, dense)
    assert len(_packed_leaves(pk)) == n0
    # already-packed leaves pass through untouched (same objects)
    pk2 = zoo.pack_params(cfg, packed)
    assert all(a is b for a, b in zip(jax.tree.leaves(pk2),
                                      jax.tree.leaves(packed)))
    # unpack_params restores dense leaves everywhere
    up = zoo.unpack_params(cfg, packed)
    assert len(_packed_leaves(up)) == 0

    # the dense fallback is numerically exact: masked-dense matmul ==
    # packed matmul on the same weight (this is the property the serving
    # fallback knob relies on — NOT roundtrip re-packing, which regroups
    # an ICP-permuted packing's columns and is lossy by construction)
    p0 = jax.tree.map(lambda a: a[0], _packed_leaves(packed)[0])
    n_in = int(packing.unpack(p0).shape[1])
    x = jnp.asarray(np.random.default_rng(1).normal(size=(3, n_in)),
                    cfg.dtype)
    y_p = nn.linear({"w": p0}, x)
    y_d = nn.linear({"w": packing.unpack(p0).T}, x)
    np.testing.assert_allclose(np.asarray(y_p, np.float32),
                               np.asarray(y_d, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_unpermuted_pack_roundtrip_stable():
    """Packing a masked-dense weight whose sparsity already matches the
    default ascending-column grouping is idempotent — the guarantee the
    pack_params docstring states (a gyro/ICP-permuted packing does NOT
    roundtrip: re-packing regroups its columns)."""
    w = jnp.asarray(RNG.normal(size=(16, 64)), jnp.float32)
    h = HiNMConfig(v=8, n=2, m=4, vector_sparsity=0.5)
    wm = packing.unpack(packing.pack(w, h))
    again = packing.unpack(packing.pack(wm, h))
    np.testing.assert_array_equal(np.asarray(again), np.asarray(wm))


def test_resolve_packed_mode(monkeypatch):
    from repro.serve.scheduler import resolve_packed_mode

    monkeypatch.delenv("REPRO_SERVE_PACKED", raising=False)
    assert resolve_packed_mode("auto") == "auto"
    assert resolve_packed_mode(True) == "pack"
    assert resolve_packed_mode(False) == "dense"
    assert resolve_packed_mode("dense") == "dense"
    with pytest.raises(ValueError, match="REPRO_SERVE_PACKED|packed"):
        resolve_packed_mode("bogus")
    # the env var overrides whatever the constructor was given
    monkeypatch.setenv("REPRO_SERVE_PACKED", "1")
    assert resolve_packed_mode("dense") == "pack"
    monkeypatch.setenv("REPRO_SERVE_PACKED", "0")
    assert resolve_packed_mode(True) == "dense"
    monkeypatch.setenv("REPRO_SERVE_PACKED", "junk")
    with pytest.raises(ValueError):
        resolve_packed_mode("auto")
