"""Checkpoint manager: round-trips (incl. bfloat16), async writes,
retention, latest-discovery, elastic restore re-placement."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore, save


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16) * 1.5,
                   "c": jnp.zeros((5,), jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    save(str(tmp_path / "ck"), t, step=7)
    restored, step = restore(str(tmp_path / "ck"), t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_bfloat16_dtype_preserved(tmp_path):
    t = {"w": jnp.full((4,), 0.375, jnp.bfloat16)}
    save(str(tmp_path / "ck"), t, step=0)
    restored, _ = restore(str(tmp_path / "ck"), t)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.full((4,), 0.375, np.float32))


def test_manager_async_retention_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = tree()
    for s in (10, 20, 30):
        mgr.save_async(t, s)
    mgr.wait()
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step-"))
    assert dirs == ["step-00000020", "step-00000030"]
    restored, step = mgr.restore_latest(t)
    assert step == 30


def test_restore_shape_mismatch_raises(tmp_path):
    t = tree()
    save(str(tmp_path / "ck"), t, step=0)
    bad = {**t, "a": jnp.zeros((4, 4))}
    with pytest.raises(ValueError):
        restore(str(tmp_path / "ck"), bad)


def test_elastic_restore_with_shardings(tmp_path):
    """Restore re-places leaves with the current mesh's shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import compat

    mesh = compat.make_mesh((1,), ("data",))
    t = {"w": jnp.arange(8, dtype=jnp.float32)}
    save(str(tmp_path / "ck"), t, step=1)
    shardings = {"w": NamedSharding(mesh, P("data"))}
    restored, _ = restore(str(tmp_path / "ck"), t, shardings=shardings)
    assert restored["w"].sharding == shardings["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8))
