"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing
from repro.core.types import HiNMConfig
from repro.kernels import ops, ref
from repro.kernels.hinm_spmm import hinm_spmm, pick_bblk
from repro.kernels.nm_select import nm_select


def make_packed(rng, n_out, n_in, v=8, sv=0.5, dtype=jnp.float32):
    cfg = HiNMConfig(v=v, n=2, m=4, vector_sparsity=sv)
    w = jnp.asarray(rng.normal(size=(n_out, n_in)).astype(np.float32)).astype(dtype)
    return w, packing.pack(w, cfg)


SHAPES = [
    # (n_out, n_in, batch, V)
    (16, 16, 4, 8),
    (64, 48, 10, 8),
    (32, 64, 33, 16),   # batch not divisible by block
    (128, 96, 7, 32),
    (64, 128, 129, 8),
]


@pytest.mark.parametrize("n_out,n_in,b,v", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hinm_spmm_sweep(rng, n_out, n_in, b, v, dtype):
    w, p = make_packed(rng, n_out, n_in, v=v, dtype=dtype)
    x = jnp.asarray(rng.normal(size=(b, n_in)).astype(np.float32)).astype(dtype)
    y_ref = ref.hinm_spmm_oracle(x.astype(jnp.float32), packing.pack(w.astype(jnp.float32), p.config))
    y_ker = hinm_spmm(
        x.T, p.vals, p.nm_idx, p.vec_idx, nn=2, mm=4, interpret=True,
        out_dtype=jnp.float32,
    ).T
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(y_ker), np.asarray(y_ref), rtol=tol, atol=tol * 10
    )


@pytest.mark.parametrize("sv", [0.25, 0.5, 0.75])
def test_hinm_spmm_sparsity_levels(rng, sv):
    w, p = make_packed(rng, 32, 32, v=8, sv=sv)
    x = jnp.asarray(rng.normal(size=(5, 32)).astype(np.float32))
    y_ref = ref.hinm_spmm_oracle(x, p)
    y_ker = ops.hinm_matmul(x, p, backend="interpret")
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref), rtol=1e-5, atol=1e-5)


def test_hinm_spmm_xla_paths_agree(rng):
    """Small-batch gather path == large-batch tile-chunked path == oracle."""
    w, p = make_packed(rng, 32, 48, v=8)
    for b in (8, 2048):
        x = jnp.asarray(rng.normal(size=(b, 48)).astype(np.float32))
        y0 = ref.hinm_spmm_oracle(x, p)
        y1 = ref.hinm_spmm_xla(x, p)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=2e-5, atol=2e-5)


def test_hinm_matmul_leading_dims(rng):
    w, p = make_packed(rng, 16, 16, v=8)
    x = jnp.asarray(rng.normal(size=(2, 3, 16)).astype(np.float32))
    y = ops.hinm_matmul(x, p, backend="interpret")
    assert y.shape == (2, 3, 16)
    y2 = ops.hinm_matmul(x.reshape(6, 16), p, backend="xla").reshape(2, 3, 16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(8, 16), (32, 64), (7, 12), (128, 512)])
@pytest.mark.parametrize("nn,mm", [(2, 4), (1, 4), (1, 2)])
def test_nm_select_sweep(rng, shape, nn, mm):
    if shape[1] % mm:
        pytest.skip("cols not divisible by M")
    w = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    out_ref = ref.nm_select_ref(w, nn, mm)
    out_ker = nm_select(w, nn=nn, mm=mm, interpret=True)
    assert np.array_equal(np.asarray(out_ker), np.asarray(out_ref))


def test_nm_select_ties_deterministic():
    w = jnp.asarray([[1.0, 1.0, 1.0, 1.0]])
    out = nm_select(w, interpret=True)
    assert np.array_equal(np.asarray(out), [[1.0, 1.0, 0.0, 0.0]])


def test_pick_bblk_respects_budget():
    b = pick_bblk(n_in=32768, k=16384, b=1024)
    # full working set with real itemsizes: xT + gather (activation dtype),
    # weights (vals + int8 slots + vec_idx), decompress one-hot + dense
    # tile, f32 accumulator
    k, v, nn, mm, it = 16384, 32, 2, 4, 2
    kn = k // mm * nn
    ws = (32768 * b * it + k * b * it + v * b * 4
          + v * kn * (it + 1) + k * 4 + v * kn * mm * it + v * k * it)
    assert ws <= 8 * 1024 * 1024
    assert pick_bblk(128, 64, 4) >= 4


def test_pick_bblk_pinned_representative_shapes():
    """Pin the chosen batch block for representative (n_in, k, B, itemsize)
    shapes so VMEM-formula regressions are caught, not silently absorbed.
    The f32 5120x2560 case is the one the old 4-byte-gather formula got
    wrong: it picked 256, which overflows the budget once the decompress
    one-hot transient is counted."""
    assert pick_bblk(32768, 16384, 1024, 2) == 32
    assert pick_bblk(13824, 5120, 2048, 2) == 128
    assert pick_bblk(5120, 2560, 1024, 2) == 256
    assert pick_bblk(5120, 2560, 1024, 4) == 128
    assert pick_bblk(1024, 512, 256, 4) == 256
    assert pick_bblk(128, 64, 4, 2) == 8


def test_decompress_tiles_matches_unpack(rng):
    w, p = make_packed(rng, 16, 16, v=8)
    tiles = ref.decompress_tiles(p.vals, p.nm_idx, p.config.m, p.config.n)
    dense = ref.scatter_dense(p)
    t, v_, k = tiles.shape
    gathered = jnp.take_along_axis(
        dense.reshape(t, v_, -1), p.vec_idx[:, None, :], axis=2
    )
    np.testing.assert_allclose(np.asarray(tiles), np.asarray(gathered), rtol=1e-6)
