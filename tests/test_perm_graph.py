"""PermGraph subsystem: plan compilation, edge folding, cache, parallelism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.types import HiNMConfig
from repro.models.module import PruneSpec
from repro.perm import ModelPermEngine, PermCache, compile_model_graph
from repro.perm.graph import (
    Container,
    EdgeKind,
    ModelPermGraph,
    compile_layer_graph,
)
from repro.perm.propagate import gqa_expand_perm
from repro.train import pruning

HCFG = HiNMConfig(v=8, n=2, m=4, vector_sparsity=0.5)

CFG = ArchConfig(
    name="dense", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, head_dim=16, max_seq=64,
    dtype=jnp.float32, hinm=HCFG,
)


# ---------------------------------------------------------------------------
# graph compilation + validation
# ---------------------------------------------------------------------------


def test_compile_dense_plan_edges():
    g = compile_model_graph(CFG).containers[0].graph
    kinds = {(e.src, e.dst): e.kind for e in g.edges}
    assert kinds[("attn/wv", "attn/wo")] == EdgeKind.GQA_EXPAND
    assert kinds[("mlp/wg", "mlp/wu")] == EdgeKind.TIED
    assert kinds[("mlp/wg", "mlp/wd")] == EdgeKind.PRODUCER
    assert g.nodes["mlp/wu"].tied_to == "mlp/wg"
    # tied partners inherit the producer's virtual search freedom
    assert g.nodes["mlp/wu"].can_permute_rows
    # residual-constrained nodes carry an identity-constraint edge
    assert any(e.kind == EdgeKind.RESIDUAL for e in g.constraints("attn/wq"))
    assert any(e.kind == EdgeKind.BLOCK_DIAGONAL
               for e in g.constraints("attn/wv"))
    # producers sort before their consumers
    order = g.topo_order()
    assert order.index("attn/wv") < order.index("attn/wo")
    assert order.index("mlp/wg") < order.index("mlp/wd")


def test_compile_all_zoo_families():
    for fam, extra in [
        ("dense", {}),
        ("moe", dict(n_experts=2, top_k=1)),
        ("encdec", dict(n_kv_heads=4, n_enc_layers=2)),
    ]:
        cfg = dataclasses.replace(CFG, name=fam, family=fam, **extra)
        mg = compile_model_graph(cfg)
        for c in mg.containers:
            c.graph.validate()
        assert len(list(mg.instances())) > 0


def test_validation_rejects_unplanned_consumer():
    with pytest.raises(ValueError, match="not a planned node"):
        compile_layer_graph([PruneSpec("a", consumers=("missing",))])


def test_validation_rejects_cycle():
    with pytest.raises(ValueError, match="cycle"):
        compile_layer_graph([
            PruneSpec("a", consumers=("b",)),
            PruneSpec("b", consumers=("a",)),
        ])


def test_validation_rejects_duplicate_and_double_fold():
    with pytest.raises(ValueError, match="duplicate"):
        compile_layer_graph([PruneSpec("a"), PruneSpec("a")])
    with pytest.raises(ValueError, match="multiple producers"):
        compile_layer_graph([
            PruneSpec("a", consumers=("c",)),
            PruneSpec("b", consumers=("c",)),
            PruneSpec("c"),
        ])


# ---------------------------------------------------------------------------
# gqa-expand round-trip
# ---------------------------------------------------------------------------


def _within_kv_perm(rng, n_kv, hd):
    return np.concatenate([kv * hd + rng.permutation(hd) for kv in range(n_kv)])


def test_gqa_expand_perm_roundtrip_preserves_attention():
    """Permuting V rows within kv heads + folding the expanded perm into
    wo's input columns leaves the attention output bit-compatible."""
    rng = np.random.default_rng(0)
    b, s, d = 2, 5, 32
    n_heads, n_kv, hd = 4, 2, 8
    g = n_heads // n_kv
    x = rng.normal(size=(b, s, d)).astype(np.float32)
    wv = rng.normal(size=(d, n_kv * hd)).astype(np.float32)
    wo = rng.normal(size=(n_heads * hd, d)).astype(np.float32)
    attn = rng.random((b, n_heads, s, s)).astype(np.float32)
    attn /= attn.sum(-1, keepdims=True)  # row-stochastic stand-in for softmax

    def forward(wv_, wo_):
        v = (x @ wv_).reshape(b, s, n_kv, hd)
        outs = []
        for h in range(n_heads):
            vh = v[:, :, h // g]                       # (B, S, hd)
            outs.append(np.einsum("bqk,bkd->bqd", attn[:, h], vh))
        return np.concatenate(outs, axis=-1) @ wo_

    y0 = forward(wv, wo)
    perm_v = _within_kv_perm(rng, n_kv, hd)
    expanded = gqa_expand_perm(perm_v, n_kv, n_heads, hd)
    assert sorted(expanded.tolist()) == list(range(n_heads * hd))
    y1 = forward(wv[:, perm_v], wo[expanded, :])
    np.testing.assert_allclose(y1, y0, rtol=1e-5, atol=1e-5)


def test_gqa_expand_perm_rejects_nothing_but_is_blockwise():
    perm_v = _within_kv_perm(np.random.default_rng(1), 2, 8)
    out = gqa_expand_perm(perm_v, 2, 4, 8)
    # every query head's slice stays inside its own head block
    for h in range(4):
        blk = out[h * 8:(h + 1) * 8]
        assert (blk // 8 == h).all()


# ---------------------------------------------------------------------------
# propagation consistency (engine level)
# ---------------------------------------------------------------------------


def _swiglu_layer(rng, d, f):
    return {
        "mlp": {
            "wg": {"w": jnp.asarray(rng.normal(size=(d, f)).astype(np.float32))},
            "wu": {"w": jnp.asarray(rng.normal(size=(d, f)).astype(np.float32))},
            "wd": {"w": jnp.asarray(rng.normal(size=(f, d)).astype(np.float32))},
        }
    }


def test_propagation_folds_compose_to_identity():
    """Searched perms folded along tied + producer edges keep the dense
    SwiGLU forward identical, and every stored perm is consistent with the
    realized weights."""
    rng = np.random.default_rng(0)
    d, f = 32, 64
    layer = _swiglu_layer(rng, d, f)
    stack = jax.tree.map(lambda a: a[None], layer)  # 1-layer stack
    specs = [
        PruneSpec("mlp/wg", tied=("mlp/wu",), consumers=("mlp/wd",)),
        PruneSpec("mlp/wd", can_permute_rows=False),
    ]
    graph = ModelPermGraph([Container("blocks", None, "blocks",
                                      compile_layer_graph(specs))])
    engine = ModelPermEngine(CFG, ocp_iters=3, icp_iters=2,
                             rng=np.random.default_rng(0), workers=1,
                             graph=graph)
    (newp, masks, packed), = engine.run_stacks({0: (stack, None)}).values()

    results = engine.states[(0, 0)].results
    perm_g, _ = results["mlp/wg"]
    assert sorted(perm_g.tolist()) == list(range(f))
    # wd got identity OCP (residual-constrained)
    perm_d, _ = results["mlp/wd"]
    assert np.array_equal(perm_d, np.arange(d))
    # tied partner's rows follow the producer: new_wu == old_wu[:, perm_g]
    old_wu = np.asarray(layer["mlp"]["wu"]["w"])
    np.testing.assert_array_equal(
        np.asarray(newp["mlp"]["wu"]["w"][0]), old_wu[:, perm_g]
    )

    def swiglu(p, x):
        h = jax.nn.silu(x @ p["mlp"]["wg"]["w"]) * (x @ p["mlp"]["wu"]["w"])
        return h @ p["mlp"]["wd"]["w"]

    x = jnp.asarray(rng.normal(size=(4, d)).astype(np.float32))
    y0 = swiglu(layer, x)
    y1 = swiglu(jax.tree.map(lambda a: a[0], newp), x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# cache + parallel dispatch
# ---------------------------------------------------------------------------


def _params():
    from repro.models import zoo

    return zoo.init(jax.random.PRNGKey(0), CFG)


def test_perm_cache_skips_repeat_searches():
    params = _params()
    cache = PermCache()
    _, m1, _, rep1 = pruning.prune_model(
        params, CFG, ocp_iters=2, icp_iters=2, permute_params=False,
        cache=cache, workers=1,
    )
    assert rep1.searches_run > 0 and rep1.cache_hits == 0
    _, m2, _, rep2 = pruning.prune_model(
        params, CFG, ocp_iters=2, icp_iters=2, permute_params=False,
        cache=cache, workers=1, rng=np.random.default_rng(123),
    )
    assert rep2.searches_run == 0
    assert rep2.cache_hits == rep1.searches_run
    for a, b in zip(jax.tree.leaves(m1), jax.tree.leaves(m2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_parallel_dispatch_matches_serial():
    params = _params()
    outs = []
    for workers in (1, 4):
        newp, masks, packed, rep = pruning.prune_model(
            params, CFG, ocp_iters=2, icp_iters=2,
            rng=np.random.default_rng(7), workers=workers,
        )
        outs.append((newp, masks, packed))
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
