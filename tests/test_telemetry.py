"""Serving telemetry: metrics registry math, trace export, scheduler wiring.

Covers the observability contracts the serving layer now leans on:
histogram percentiles vs a numpy reference (log-bucket edge cases and
empty histograms included), registry snapshot -> JSON -> restore
round-trips, Chrome-trace structural validity (monotonic timestamps,
matched B/E pairs — the committed bench trace too, so the artifact that
claims to open in Perfetto actually parses), and the conformance rule
that telemetry on vs off yields bit-identical tokens."""
import json
import math
import os
import re

import jax
import numpy as np
import pytest

from repro.configs.base import load_arch
from repro.models import zoo
from repro.serve import (Request, SamplingParams, Scheduler, SpecConfig,
                         Telemetry)
from repro.serve.telemetry import (GLOBAL, MetricsRegistry, TraceRecorder,
                                   metrics as tm)


# ---------------------------------------------------------------------------
# histogram percentile math


@pytest.mark.parametrize("dist", ["lognormal", "uniform", "constant"])
def test_histogram_percentiles_match_numpy(dist):
    rng = np.random.default_rng(0)
    if dist == "lognormal":
        xs = rng.lognormal(mean=-7, sigma=2.0, size=500)  # us..s latencies
    elif dist == "uniform":
        xs = rng.uniform(1e-5, 1e-2, size=500)
    else:
        xs = np.full(100, 3.14e-3)
    h = tm.Histogram("t")
    for x in xs:
        h.observe(float(x))
    assert h.exact
    for q in (0, 10, 50, 90, 99, 100):
        assert h.percentile(q) == pytest.approx(np.percentile(xs, q), rel=1e-12)
    assert h.mean == pytest.approx(xs.mean())
    assert h.count == len(xs)
    assert h.min == pytest.approx(xs.min()) and h.max == pytest.approx(xs.max())


def test_histogram_empty_and_single():
    h = tm.Histogram("t")
    assert math.isnan(h.percentile(50)) and math.isnan(h.mean)
    assert h.count == 0
    h.observe(0.25)
    assert h.percentile(50) == 0.25 == h.percentile(99)


def test_histogram_log_bucket_edges():
    h = tm.Histogram("t", lo=1e-6, growth=2.0, n_buckets=10)
    # underflow (<= lo, including 0 and negatives) lands in bucket 0
    for v in (0.0, -1.0, 1e-9, 1e-6):
        assert h._bucket(v) == 0
    # beyond the top bound -> overflow bucket, never out of range
    assert h._bucket(1e6) == h.n_buckets
    # every observed value lies within its bucket's (lower, upper] range
    rng = np.random.default_rng(1)
    for v in np.concatenate([rng.lognormal(-10, 4, 200),
                             1e-6 * 2.0 ** np.arange(12)]):  # exact bounds
        v = float(v)
        i = h._bucket(v)
        down, up = h.bucket_bounds(i)
        assert v <= up and (i == 0 or v > down * (1 - 1e-12))
    h2 = tm.Histogram("t2")
    for v in (1e-5, 3e-4, 0.1):
        h2.observe(v)
    assert sum(h2.counts) == h2.count == 3


def test_histogram_bucket_estimate_beyond_cap():
    rng = np.random.default_rng(2)
    xs = rng.lognormal(mean=-6, sigma=1.5, size=2000)
    h = tm.Histogram("t", sample_cap=64)  # force the estimate path
    for x in xs:
        h.observe(float(x))
    assert not h.exact
    for q in (50, 90, 99):
        true = np.percentile(xs, q)
        est = h.percentile(q)
        # bounded by the bucket's geometric width around the true value
        assert true / h.growth ** 2 <= est <= true * h.growth ** 2
        assert h.min <= est <= h.max


def test_histogram_weighted_observe():
    h = tm.Histogram("t")
    h.observe(2e-3, n=5)
    h.observe(8e-3)
    assert h.count == 6
    assert h.sum == pytest.approx(5 * 2e-3 + 8e-3)
    assert h.percentile(50) == pytest.approx(2e-3)


# ---------------------------------------------------------------------------
# registry snapshot / restore / exposition


def _populated_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    reg.counter("reqs").inc(7)
    reg.counter("dispatch", labels={"backend": "pallas"}).inc()
    reg.counter("dispatch", labels={"backend": "gather"}).inc(3)
    g = reg.gauge("free_pages")
    for v in (10, 3, 8):
        g.set(v)
    h = reg.histogram("lat", labels={"phase": "decode"})
    for v in (1e-4, 5e-4, 2e-3):
        h.observe(v)
    reg.histogram("empty")
    return reg


def test_registry_snapshot_json_restore_roundtrip():
    reg = _populated_registry()
    snap = reg.snapshot()
    restored = MetricsRegistry.from_snapshot(json.loads(json.dumps(snap)))
    assert restored.snapshot() == snap
    # restored instruments stay live, not just readable
    assert restored.counter("reqs").value == 7
    g = restored.gauge("free_pages")
    assert (g.value, g.min, g.max) == (8, 3, 10)  # low-water mark survives
    h = restored.histogram("lat", labels={"phase": "decode"})
    assert h.percentile(50) == pytest.approx(5e-4)
    e = restored.histogram("empty")
    assert e.count == 0 and math.isnan(e.min)


def test_registry_identity_and_kind_conflicts():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.counter("a", {"x": "1"}) is not reg.counter("a", {"x": "2"})
    with pytest.raises(ValueError):
        reg.gauge("a")  # same name, different kind


def test_prometheus_exposition():
    text = _populated_registry().render_prometheus()
    assert "# TYPE reqs counter" in text
    assert "reqs 7" in text
    assert 'dispatch{backend="pallas"} 1' in text
    assert "# TYPE free_pages gauge" in text
    assert 'lat_count{phase="decode"} 3' in text
    assert 'le="+Inf"' in text
    # cumulative buckets end at the total count
    last_bucket = [l for l in text.splitlines() if 'lat_bucket' in l][-1]
    assert last_bucket.endswith(" 3")


# ---------------------------------------------------------------------------
# chrome trace export


def _validate_chrome_trace(doc: dict) -> None:
    evs = doc["traceEvents"]
    assert evs, "empty trace"
    spans = [e for e in evs if e["ph"] in ("B", "E")]
    last_ts = -1.0
    stacks: dict[tuple, list] = {}
    for e in spans:
        assert e["ts"] >= 0
        assert e["ts"] >= last_ts, "timestamps not monotonic"
        last_ts = e["ts"]
        key = (e["pid"], e["tid"])
        if e["ph"] == "B":
            stacks.setdefault(key, []).append(e["name"])
        else:
            assert stacks.get(key), f"E without open B on {key}"
            stacks[key].pop()
    assert all(not s for s in stacks.values()), "unclosed B events"
    for e in evs:
        assert e["ph"] in ("B", "E", "M", "i")
        if e["ph"] == "i":  # instant events need a scope to parse
            assert e["s"] in ("t", "p", "g")
            assert e["ts"] >= 0


def test_open_span_auto_closed_on_export():
    """`begin` without `end` — an abandoned lifecycle — must export as a
    matched, zero-or-positive-width B/E pair marked auto_closed."""
    tr = TraceRecorder()
    t = tr.epoch
    done = tr.begin("req0", "decode", t + 0.001, rid=0)
    tr.end(done, t + 0.003, tokens=4)
    abandoned = tr.begin("req1", "decode", t + 0.002, rid=1)
    assert abandoned.open and abandoned.duration == 0.0
    doc = tr.chrome_trace()
    _validate_chrome_trace(doc)
    assert not abandoned.open
    assert abandoned.args.get("auto_closed") is True
    assert "auto_closed" not in done.args  # explicit ends stay unmarked
    assert tr.finalize() == 0  # idempotent: nothing left open


def test_trace_recorder_export_valid(tmp_path):
    tr = TraceRecorder()
    t = tr.epoch
    tr.span("scheduler", "prefill[b8]", t + 0.001, t + 0.004, requests=2)
    tr.span("scheduler", "decode_chunk", t + 0.004, t + 0.009, steps=4)
    req = Request(rid=3, prompt=np.arange(4, dtype=np.int32))
    tr.request_span(req, "queued", t + 0.0005, t + 0.001)
    tr.request_span(req, "decode", t + 0.004, t + 0.009)
    assert [s.name for s in req.spans] == ["queued", "decode"]
    assert req.spans[0].duration == pytest.approx(0.0005)
    doc = tr.chrome_trace()
    _validate_chrome_trace(doc)
    names = {e["args"]["name"] for e in doc["traceEvents"] if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert {"scheduler", "req3"} <= names
    p = tmp_path / "trace.json"
    tr.dump(str(p))
    _validate_chrome_trace(json.loads(p.read_text()))


def test_committed_bench_trace_is_perfetto_valid():
    """The trace JSON serve_bench commits must stay structurally loadable."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_serve_trace.json")
    if not os.path.exists(path):
        pytest.skip("no committed bench trace")
    with open(path) as f:
        doc = json.load(f)
    _validate_chrome_trace(doc)
    tracks = {e["args"]["name"] for e in doc["traceEvents"]
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "scheduler" in tracks
    assert any(t.startswith("req") for t in tracks)


# ---------------------------------------------------------------------------
# scheduler wiring


@pytest.fixture(scope="module")
def small_model():
    cfg = load_arch("qwen2_0_5b").reduced(n_layers=2, d_model=64, n_heads=4,
                                          n_kv_heads=2, d_ff=128, vocab=128,
                                          head_dim=16)
    return cfg, zoo.init(jax.random.PRNGKey(0), cfg)


def _workload(cfg, n=6, max_new=6):
    rng = np.random.default_rng(7)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
                    params=SamplingParams(max_new_tokens=max_new), arrival=i)
            for i in range(n)]


def test_telemetry_on_off_tokens_identical(small_model):
    cfg, params = small_model
    runs = {}
    for mode in (False, True):
        sched = Scheduler(cfg, params, max_slots=2, max_seq=64,
                          decode_chunk=4, telemetry=mode)
        reqs = _workload(cfg)
        sched.run(reqs)
        runs[mode] = [r.tokens for r in reqs]
    assert runs[True] == runs[False]


def test_telemetry_default_off_and_knob(small_model):
    cfg, params = small_model
    assert Scheduler(cfg, params, max_slots=2, max_seq=64).telemetry.enabled \
        is False
    from repro.perf_knobs import knobs

    with knobs(telemetry=True):
        assert Scheduler(cfg, params, max_slots=2,
                         max_seq=64).telemetry.enabled is True


def test_scheduler_instruments_populate(small_model):
    cfg, params = small_model
    tele = Telemetry(enabled=True)
    sched = Scheduler(cfg, params, max_slots=2, max_seq=64, decode_chunk=4,
                      telemetry=tele)
    reqs = _workload(cfg)
    sched.run(reqs)
    reg = tele.registry
    assert reg.histogram("serve_admission_wait_seconds").count == len(reqs)
    assert reg.histogram("serve_decode_step_seconds").count \
        == sched.stats.decode_steps
    assert reg.histogram("serve_host_gap_seconds").count > 0
    # per-bucket prefill histograms carry the bucket label
    assert reg.get("serve_prefill_seconds", {"bucket": "8"}) is not None
    # pool gauges: everything released at drain, low-water mark below start
    assert reg.gauge("kv_slots_in_use").value == 0
    assert reg.gauge("kv_slots_in_use").max == 2
    free = reg.gauge("kv_free_pages")
    assert free.value == free.max and free.min < free.max
    assert reg.gauge("kv_pool_bytes").value == sched.kv.pool_bytes()
    # stats histograms fill regardless of the knob; spans landed per request
    assert sched.stats.ttft_hist.count == len(reqs)
    assert all(any(s.name == "decode" for s in r.spans) for r in reqs)
    snap = sched.metrics_snapshot()
    assert {"metrics", "global", "enabled"} <= set(snap)


def test_spec_loop_instruments_and_rollback_counter(small_model):
    cfg, params = small_model
    # unfused per-cycle chain: the draft/verify wall-clock split and the
    # host-side rollback sweep are observable once per verify cycle
    tele = Telemetry(enabled=True)
    sched = Scheduler(cfg, params, max_slots=2, max_seq=64, decode_chunk=4,
                      spec=SpecConfig(k=2, drafter="ngram", fused=False),
                      telemetry=tele)
    sched.run(_workload(cfg, n=4, max_new=8))
    reg = tele.registry
    draft = reg.histogram("serve_spec_draft_seconds")
    verify = reg.histogram("serve_spec_verify_seconds")
    assert draft.count == verify.count == sched.stats.verify_steps
    assert reg.counter("kv_rollback_sweeps").value == sched.stats.verify_steps
    acc = reg.histogram("serve_spec_window_acceptance")
    assert acc.count > 0
    assert 0.0 <= acc.percentile(99) <= 1.0
    # fused scan (the default): draft, verify and rollback all live inside
    # one dispatch, so there is no per-cycle wall-clock split to observe —
    # instead the dispatch counter covers every cycle and acceptance is
    # still observed per harvest window
    tele_f = Telemetry(enabled=True)
    sched_f = Scheduler(cfg, params, max_slots=2, max_seq=64, decode_chunk=4,
                        spec=SpecConfig(k=2, drafter="ngram"),
                        telemetry=tele_f)
    sched_f.run(_workload(cfg, n=4, max_new=8))
    reg_f = tele_f.registry
    assert sched_f.spec.fused
    assert reg_f.histogram("serve_spec_draft_seconds").count == 0
    assert reg_f.histogram("serve_spec_verify_seconds").count == 0
    d = reg_f.counter("serve_spec_dispatches").value
    assert d > 0 and d * sched_f._spec_cycles == sched_f.stats.verify_steps
    acc_f = reg_f.histogram("serve_spec_window_acceptance")
    assert acc_f.count > 0
    assert 0.0 <= acc_f.percentile(99) <= 1.0


def test_kernel_dispatch_counters(small_model):
    cfg, params = small_model
    from repro.perf_knobs import knobs

    tm.reset_global()
    with knobs(paged_attn="interpret"):
        sched = Scheduler(cfg, params, max_slots=2, max_seq=64, decode_chunk=4)
        sched.run(_workload(cfg, n=2))
    forced = GLOBAL.value("paged_attn_dispatch",
                          {"decision": "interpret", "reason": "forced"})
    assert forced and forced >= 1  # once per XLA trace, not per step
    tm.reset_global()
    with knobs(paged_attn="off"):
        sched = Scheduler(cfg, params, max_slots=2, max_seq=64, decode_chunk=4)
        sched.run(_workload(cfg, n=2))
    # scheduler resolved "off" itself -> layers never even ask the kernel
    assert GLOBAL.value("paged_attn_dispatch",
                        {"decision": "gather", "reason": "knob-off"}) is None


def test_paged_attn_deferral_reasons(small_model):
    cfg, params = small_model
    from repro.perf_knobs import knobs

    with knobs(paged_attn="interpret"):
        sched = Scheduler(cfg, params, max_slots=2, max_seq=64, page=None)
    assert sched.paged_attn == "off"
    assert sched.telemetry.registry.value(
        "serve_paged_attn_deferred", {"reason": "pool-not-paged"}) == 1


def test_abandoned_request_trace_stays_valid(small_model):
    """Walking away from a scheduler mid-decode (no drain, no finish)
    must still export a Perfetto-valid trace: the in-flight requests'
    open decode spans auto-close at export instead of leaving unmatched
    B events."""
    cfg, params = small_model
    tele = Telemetry(enabled=True)
    sched = Scheduler(cfg, params, max_slots=2, max_seq=64, decode_chunk=4,
                      telemetry=tele)
    for r in _workload(cfg, n=2, max_new=32):
        sched.submit(r)
    sched.step()
    sched.step()  # requests are now mid-decode with OPEN spans
    assert any(s.open for s in tele.tracer.events), \
        "no open decode span to abandon"
    doc = tele.tracer.chrome_trace()  # abandon: export without finishing
    _validate_chrome_trace(doc)
    assert any(s.args.get("auto_closed") for s in tele.tracer.events)
    assert all(s.t1 is not None for s in tele.tracer.events)


def test_prometheus_histogram_spec_compliance():
    """Text-format contract (the round-trip pin): `le` bounds strictly
    increase, bucket counts are CUMULATIVE, the +Inf bucket equals
    `_count`, and `_sum` is the exact total — re-counted from the raw
    observations, not just self-consistent."""
    reg = MetricsRegistry()
    h = reg.histogram("lat", labels={"phase": "decode"})
    vals = [1e-5, 2e-4, 2e-4, 3e-3, 0.5]
    for v in vals:
        h.observe(v)
    text = reg.render_prometheus()
    buckets = []
    for line in text.splitlines():
        if line.startswith("lat_bucket"):
            le = re.search(r'le="([^"]+)"', line).group(1)
            buckets.append((math.inf if le == "+Inf" else float(le),
                            int(float(line.rsplit(" ", 1)[1]))))
    assert buckets, "no bucket lines rendered"
    les, counts = zip(*buckets)
    assert list(les) == sorted(les), "le bounds not increasing"
    assert all(a <= b for a, b in zip(counts, counts[1:])), \
        "bucket counts are not cumulative"
    assert les[-1] == math.inf and counts[-1] == len(vals)
    # every cumulative count matches a recount of the raw observations
    for le, c in buckets:
        assert c == sum(1 for v in vals if v <= le * (1 + 1e-12)), \
            f"le={le}: cumulative count {c} wrong"
    s = re.search(r"^lat_sum\{[^}]*\} (\S+)$", text, re.M)
    assert float(s.group(1)) == pytest.approx(sum(vals))
    c = re.search(r"^lat_count\{[^}]*\} (\S+)$", text, re.M)
    assert int(float(c.group(1))) == len(vals)


def test_async_admission_telemetry_attribution(small_model):
    """Telemetry under overlapped admission: the prepare/commit split
    must not lose per-request attribution (every request still gets its
    admission-wait observation and a closed decode span), overlapped
    admissions are counted, and the `serve_inflight_syncs` canary stays
    zero — instrumentation must never force a blocking host sync while a
    decode chunk is in flight."""
    cfg, params = small_model
    tele = Telemetry(enabled=True)
    sched = Scheduler(cfg, params, max_slots=2, max_seq=64, decode_chunk=4,
                      async_admission=True, telemetry=tele)
    assert sched.async_admission
    reqs = _workload(cfg)
    sched.run(reqs)
    reg = tele.registry
    assert reg.counter("serve_overlap_admissions").value > 0, \
        "no admission ever overlapped a decode chunk"
    assert reg.counter("serve_inflight_syncs").value == 0
    assert reg.histogram("serve_admission_wait_seconds").count == len(reqs)
    assert reg.histogram("serve_decode_step_seconds").count \
        == sched.stats.decode_steps
    assert all(any(s.name == "decode" for s in r.spans) for r in reqs)
    assert all(s.t1 is not None for r in reqs for s in r.spans)
    _validate_chrome_trace(tele.tracer.chrome_trace())


# ---------------------------------------------------------------------------
# satellite pins: NaN sentinels + prefill_traces alias


def test_unfinished_request_stats_are_nan():
    req = Request(rid=0, prompt=np.arange(4, dtype=np.int32))
    req.submit_time = 123.0  # submitted but never prefilled (cancelled)
    assert math.isnan(req.ttft)
    assert math.isnan(req.tokens_per_second)
    assert math.isnan(req.tpot)
    req.first_token_time = 124.0  # first token but never finished
    assert req.ttft == pytest.approx(1.0)
    assert math.isnan(req.tokens_per_second)
    req.finish_time = 125.0
    req.tokens = [1, 2, 3]
    assert req.tokens_per_second == pytest.approx(2.0)
    assert req.tpot == pytest.approx(0.5)


def test_prefill_traces_alias_tracks_registry(small_model):
    cfg, params = small_model
    sched = Scheduler(cfg, params, max_slots=2, max_seq=64, decode_chunk=4)
    sched.run(_workload(cfg, n=3))
    n = sched.telemetry.registry.counter("serve_prefill_traces").value
    assert n >= 1
    # the alias still reads the same instrument, but is now deprecated in
    # favour of the registry counter — reading it must say so exactly once
    with pytest.warns(DeprecationWarning, match="serve_prefill_traces"):
        assert sched.prefill_traces == n
