"""Table 2 analogue — gradual pruning on a (reduced) BERT-like LM.

The paper compares gyro-permuted HiNM against VENOM (same sparsity
pattern, no gyro permutation) under gradual pruning on BERT-base. Proxy
here: train a small LM on the synthetic pipeline, gradually prune to 75%
HiNM with (a) gyro permutation and (b) no permutation (VENOM-pattern
proxy), and report the final eval loss of each (lower = better, maps to
the paper's F1 ordering).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import load_arch
from repro.data.pipeline import SyntheticLMData
from repro.launch.mesh import make_host_mesh
from repro.models import zoo
from repro.optim import cosine_schedule, make_optimizer
from repro.train import gradual, pruning, steps as tsteps


def eval_loss(cfg, params, masks, data, jitted_loss, steps=4):
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(10_000 + i).items()}
        losses.append(float(jitted_loss(pruning.apply_masks(params, masks), b)))
    return float(np.mean(losses))


def run(total_steps: int = 200) -> None:
    cfg = load_arch("qwen2_0_5b").reduced(n_layers=2, d_model=128, n_heads=4,
                                          n_kv_heads=2, d_ff=256, vocab=512,
                                          head_dim=32)
    mesh = make_host_mesh()
    data = SyntheticLMData(cfg.vocab, 64, 16, seed=0)
    opt = make_optimizer("adamw")

    def loss_only(params, batch):
        x = zoo.forward(params, cfg, batch["tokens"])
        return tsteps.chunked_xent(params, cfg, x, batch["labels"])

    jitted_loss = jax.jit(loss_only)

    # phases: dense pretrain -> vector ramp -> N:M switch -> recovery
    dense_until = total_steps * 2 // 5
    nm_step = total_steps * 4 // 5  # short recovery budget (the paper's regime)

    # shared dense pretraining (both methods branch from the same weights)
    params0 = zoo.init(jax.random.PRNGKey(0), cfg)
    step_fn, _ = tsteps.make_train_step(
        cfg, mesh, lr_fn=cosine_schedule(5e-3, 10, total_steps))
    jitted = jax.jit(step_fn)
    none_masks = jax.tree.map(lambda x: None, params0)
    opt0 = opt.init(params0)
    for i in range(dense_until):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params0, opt0, m, _ = jitted(params0, opt0, none_masks, b, i, None)

    results, pre = {}, {}
    for method in ("gyro", "noperm"):
        t0 = time.perf_counter()
        params, opt_state, masks = params0, opt0, none_masks
        sched = gradual.GradualSchedule(
            target=cfg.hinm, start_step=dense_until,
            vector_end_step=nm_step - 10, nm_step=nm_step, update_every=10)
        mask_cb = gradual.make_mask_schedule(cfg, sched, method=method)

        class S:  # minimal LoopState stand-in for the schedule callback
            pass

        st = S()
        for i in range(dense_until, total_steps):
            st.params = params
            new_masks = mask_cb(i, st)
            params = st.params
            if new_masks is not None:
                masks = new_masks
            if i == nm_step:  # pre-recovery readout right at the N:M switch
                pre[method] = eval_loss(cfg, params, masks, data, jitted_loss)
            b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            params, opt_state, m, _ = jitted(params, opt_state, masks, b, i, None)
        us = (time.perf_counter() - t0) * 1e6 / (total_steps - dense_until)
        results[method] = eval_loss(cfg, params, masks, data, jitted_loss)
        emit(f"table2_gradual_{method}", us,
             f"final_eval_loss={results[method]:.4f};"
             f"pre_recovery_loss={pre[method]:.4f}")
    emit("table2_gradual_delta", 0.0,
         f"final_gyro_minus_noperm={results['gyro'] - results['noperm']:.4f};"
         f"pre_recovery_gyro_minus_noperm={pre['gyro'] - pre['noperm']:.4f}")


if __name__ == "__main__":
    run()
