import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimb driver (EXPERIMENTS.md §Perf).

Runs one (arch, shape) cell under a set of knob variants and reports the
three roofline terms + artifact memory for each, so every
hypothesis -> change -> measure cycle is one invocation:

  PYTHONPATH=src python -m benchmarks.perf_iter --arch qwen2_5_14b \
      --shape decode_32k --variants baseline,embed_fs,packed_model_t
"""

import argparse
import json
import time

VARIANTS = {
    # name: knob overrides
    "baseline": {},
    "embed_fs": {"embed_feature_shard": True},
    "packed_model_t": {"packed_t_axes": "model"},
    "packed_model_t_embed_fs": {"packed_t_axes": "model", "embed_feature_shard": True},
    "seq_shard_cache": {"decode_seq_shard": True, "embed_feature_shard": True},
    "xent_chunk_128": {"xent_chunk": 128, "embed_feature_shard": True},
    "kvblock_1024": {"kv_block": 1024, "embed_feature_shard": True},
    "shard_map": {"packed_t_axes": "model_only", "packed_shard_map": True},
    "seq_par_decode": {"packed_t_axes": "model_only", "packed_shard_map": True,
                       "decode_seq_shard": True, "seq_parallel_decode": True},
    "shard_map_embed_fs": {"packed_t_axes": "model_only", "packed_shard_map": True,
                           "embed_feature_shard": True},
    "all_opt": {"embed_feature_shard": True, "packed_t_axes": "both"},
}


def measure(arch: str, shape: str, overrides: dict) -> dict:
    import jax

    from benchmarks import roofline as rl
    from repro import perf_knobs
    from repro.configs.base import load_arch
    from repro.launch import cells as cell_lib
    from repro.launch import hlo_stats
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    cfg = load_arch(arch)
    with perf_knobs.knobs(**overrides):
        # full-depth artifact: memory + collective schedule
        t0 = time.time()
        cell = cell_lib.build_cell(cfg, shape, mesh)
        compiled = cell_lib.lower_cell(cell, mesh).compile()
        cs = hlo_stats.cost_summary(compiled)
        coll = hlo_stats.collective_bytes_nested(
            compiled.as_text(), cfg.n_layers // rl._period(cfg))
        # probe: loop-corrected flops
        stats = rl.extrapolated_cell_stats(cfg, shape, mesh)
        compile_s = time.time() - t0

    mem_bytes = cs["argument_bytes"] + cs["output_bytes"] + 2 * cs["temp_bytes"]
    return {
        "arch": arch, "shape": shape, "overrides": overrides,
        "compute_term_s": stats["flops"] / rl.PEAK_FLOPS,
        "memory_term_s": mem_bytes / rl.HBM_BW,
        "collective_term_s": coll["total_bytes"] / rl.ICI_BW,
        "coll_by_kind_gb": {k: round(v / 1e9, 2) for k, v in coll["bytes"].items()},
        "hbm_gb": (cs["argument_bytes"] + cs["temp_bytes"] + cs["output_bytes"]
                   - cs["alias_bytes"]) / 1e9,
        "flops_per_device": stats["flops"],
        "coll_bytes_per_device": coll["total_bytes"],
        "compile_seconds": round(compile_s, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    names = args.variants.split(",")
    print(f"{'variant':26s} {'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
          f"{'hbm_GB':>8s} {'dominant':>10s}")
    for name in names:
        r = measure(args.arch, args.shape, VARIANTS[name])
        terms = {"compute": r["compute_term_s"], "memory": r["memory_term_s"],
                 "collective": r["collective_term_s"]}
        dom = max(terms, key=terms.get)
        r["dominant"] = dom
        with open(os.path.join(
                args.out, f"{args.arch}__{args.shape}__{name}.json"), "w") as f:
            json.dump(r, f, indent=1)
        print(f"{name:26s} {r['compute_term_s']:10.3e} {r['memory_term_s']:10.3e} "
              f"{r['collective_term_s']:10.3e} {r['hbm_gb']:8.2f} {dom:>10s}",
              flush=True)


if __name__ == "__main__":
    main()
