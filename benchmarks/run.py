"""One function per paper table. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # all tables
  PYTHONPATH=src python -m benchmarks.run fig5       # substring filter

The roofline analysis is separate (it needs the 512-device dry-run
artifacts): ``PYTHONPATH=src python -m benchmarks.roofline``.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        compression_bench,
        fig3_fig4_oneshot,
        fig5_latency,
        permgraph_bench,
        serve_bench,
        table1_deit,
        table2_gradual,
        table3_ablation,
    )

    suites = {
        "fig3_fig4": fig3_fig4_oneshot.run,
        "table1": table1_deit.run,
        "table2": table2_gradual.run,
        "table3": table3_ablation.run,
        "fig5": fig5_latency.run,
        "compression": compression_bench.run,
        "permgraph": permgraph_bench.run,
        "serve": serve_bench.run,
        "serve_spec": serve_bench.run_spec,
        "serve_replay": serve_bench.run_replay,
    }
    pattern = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if pattern and pattern not in name:
            continue
        fn()


if __name__ == "__main__":
    main()
