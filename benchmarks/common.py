"""Shared helpers for the paper-table benchmarks.

No ImageNet/SQuAD on this box: the paper's accuracy deltas are driven by
the retained-saliency objective the permutation explicitly optimises
(Eq. 1), so benchmarks report retained-saliency fractions on real-shaped
weight tensors plus end-to-end eval-loss on a synthetically trained LM
(DESIGN.md §7). Timing uses wall-clock over repeated calls.
"""
from __future__ import annotations

import time

import jax
import numpy as np


def time_us(fn, *args, repeat: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r) if hasattr(r, "block_until_ready") or isinstance(
            r, jax.Array
        ) else None
    t0 = time.perf_counter()
    for _ in range(repeat):
        r = fn(*args)
        if isinstance(r, jax.Array):
            r.block_until_ready()
    return (time.perf_counter() - t0) / repeat * 1e6


def structured_weights(rng: np.random.Generator, n_out: int, n_in: int) -> np.ndarray:
    """Synthetic weights with realistic row/column scale structure
    (per-channel variance spread, as in trained conv/linear layers)."""
    row = np.exp(rng.normal(scale=0.6, size=(n_out, 1)))
    col = np.exp(rng.normal(scale=0.6, size=(1, n_in)))
    return (rng.normal(size=(n_out, n_in)) * row * col).astype(np.float32)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
