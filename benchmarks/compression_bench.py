"""Beyond-paper: error-feedback top-k gradient compression — bytes sent
per step vs k fraction, and the residual-energy decay that justifies it."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.optim.compression import ef_topk_compress, ef_topk_init


def run() -> None:
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(1 << 16,)).astype(np.float32))}
    for kf in (0.01, 0.05, 0.25):
        err = ef_topk_init(g)
        sent_bytes = 0
        residual = 0.0
        for _ in range(5):
            sent, err = ef_topk_compress(g, err, k_frac=kf)
            sent_bytes += int((np.asarray(sent["w"]) != 0).sum()) * 8  # value+index
            residual = float(jnp.linalg.norm(err["w"]) / jnp.linalg.norm(g["w"]))
        dense_bytes = 5 * g["w"].size * 4
        emit(f"compression_topk_{kf}", 0.0,
             f"bytes_ratio={sent_bytes/dense_bytes:.4f};resid_norm={residual:.3f}")


if __name__ == "__main__":
    run()
