"""Serving benchmarks: batching policy, paged KV pool, bucketed prefill.

Three comparisons on the same jitted decode machinery (serve.Scheduler):

  1. continuous vs static admission on a skewed staggered-arrival workload
     (one long request per static gang) — the structural utilization gap,
     not wall-clock noise, drives the speedup; the continuous row admits
     asynchronously (double-buffered against the in-flight decode chunk),
     and an `admission` section pins async vs sync throughput and the
     host-overhead fraction the overlap removes;
  2. paged pool vs PR 2 stripe pool on the same workload — KV pool bytes
     at the benchmark's occupancy (pages cover live tokens; stripes pin
     slots x max_seq) and the throughput cost of the page gather;
  3. exact vs bucketed admission prefill on a mixed-length workload
     (8 distinct prompt lengths) — the compile-count column: distinct
     prefill jits traced before vs after power-of-two bucketing.

  4. sharded vs single-device decode: the same continuous paged workload
     on a data mesh over every visible device (the CI multi-device job
     forces 4 fake host devices via XLA_FLAGS; locally this is usually a
     1-device mesh, which still exercises the sharded code path). Fake
     host devices share one CPU, so the column tracks sharding overhead
     and conformance, not real scaling.

  5. paged-attention kernel vs gather: decode step time with the Pallas
     block-table kernel (kernels/paged_attn) vs the pool[bt] gather path.
     Off-TPU the kernel runs under the Pallas interpreter, so that column
     is correctness-grade only; compiled numbers need a TPU.

  6. packed vs dense weights: the same workload served through hinm_spmm
     (PackedHiNM projections) vs the masked-dense fallback
     (``packed="dense"``) — weight bytes per decode token and step time.

  7. telemetry off vs on vs flight-recorder: the observability layer's
     decode-throughput cost (best-of-2 per mode, telemetry and recorder
     each asserted <= 3% when floors are active; both are off by
     default). The on-run dumps `BENCH_serve_metrics.json` (registry
     snapshot) and `BENCH_serve_trace.json` (Perfetto-loadable Chrome
     trace); a recording run dumps `BENCH_serve_flightrec.jsonl` and is
     replayed in-process — event- and token-identical, the determinism
     contract — before the record ships as a CI artifact. Every row also
     publishes p50/p99 TTFT, p50/p99 decode step time, a host-overhead
     fraction, and the raw step-time histogram snapshot that
     `benchmarks/roofline.py` restores for its measured-vs-analytic
     attainment column.

  8. traffic replay (``run_replay`` -> `BENCH_serve_replay.json`): a
     Poisson-arrival multi-tenant workload — many short requests sharing
     a long system-prompt prefix, a few long unshared requests — served
     with prefix sharing off / on / on+chunked prefill / on+sharded.
     Columns: goodput, prefix-hit-rate, live-page occupancy (peak +
     integrated page-steps), prefill rows computed, worst single-step
     prefill burst, p50/p99 TTFT.  Asserted (deterministic admission
     order): hit rate > 0, CoW exercised, sharing's live-page occupancy
     and prefill compute strictly below the no-sharing run, chunking
     bounds the worst per-step prefill burst to `chunk` rows per slot;
     hit-rate / occupancy / goodput floors vs the committed baseline.

Writes `BENCH_serve.json` (CI uploads it as an artifact; the paged pool
must come in at <= 0.5x the stripe pool bytes or the smoke run fails) and
prints the usual ``name,us_per_call,derived`` CSV rows.  When a committed
baseline JSON already exists, regression floors are asserted against it
(generous tok/s floors for noisy runners, firm byte floors); regenerate
baselines with ``REPRO_BENCH_NO_FLOORS=1``.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import emit

PAGE, N_PAGES = 16, 12  # pool provisioned for occupancy, not capacity


def _num(x: float):
    """NaN -> None so percentile columns survive strict JSON parsers."""
    return None if x != x else float(x)


def _json_hist(snap: dict) -> dict:
    from repro.serve.telemetry.metrics import _json_safe

    return _json_safe(snap)


def _workload(cfg, rng, n_requests: int, slots: int, prompt_len: int):
    from repro.serve import Request, SamplingParams

    reqs = []
    for i in range(n_requests):
        # one long request per `slots`-wide static gang, rest short
        new = 64 if i % slots == 0 else 8
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, (prompt_len,)).astype(np.int32),
            params=SamplingParams(max_new_tokens=new),
            arrival=i,  # staggered: one request per scheduler step
        ))
    return reqs


def _serve(cfg, params, reqs, policy: str, slots: int, max_seq: int,
           **sched_kw):
    from repro.serve import Request, SamplingParams, Scheduler

    sched = Scheduler(cfg, params, max_slots=slots, max_seq=max_seq,
                      decode_chunk=4, policy=policy, **sched_kw)
    return _drive(sched, reqs)


def _drive(sched, reqs):
    from repro.serve import Request, SamplingParams
    # warm the jitted kernels outside the timed region: the decode chunk,
    # and the admission prefill/insert for every group width 1..slots the
    # admission policy can form (one XLA trace per batch shape). The timed
    # region then measures scheduling, not compilation.
    for k in range(1, sched.max_slots + 1):
        warm = [Request(rid=-1 - i, prompt=reqs[0].prompt.copy(),
                        params=SamplingParams(max_new_tokens=2))
                for i in range(k)]
        sched.run(warm)
        sched.reset()
    t0 = time.perf_counter()
    sched.run(reqs)
    makespan = time.perf_counter() - t0
    st = sched.stats
    # host overhead: makespan not attributed to the timed prefill/decode
    # dispatch windows (admission bookkeeping, harvest, queue management).
    # decode_seconds already contains the unfused chain's draft dispatches
    # (spec_draft_seconds is a SLICE of it, not an addition), so the gap
    # subtracts each second exactly once.
    host_overhead = (max(0.0, makespan - st.prefill_seconds - st.decode_seconds)
                     / max(makespan, 1e-9))
    out = {
        "policy": sched.policy,
        "tokens": st.tokens_generated,
        "requests": st.requests_finished,
        "decode_steps": st.decode_steps,
        "makespan_seconds": makespan,
        "tokens_per_second": st.tokens_generated / max(makespan, 1e-9),
        "decode_tokens_per_second": st.decode_tokens_per_second,
        # per-step device time, net of the unfused spec chain's draft
        # dispatches — those are reported separately below, so a drafter
        # swap moves one column instead of silently skewing this one
        "decode_step_us": (1e6 * (st.decode_seconds - st.spec_draft_seconds)
                           / max(st.decode_steps, 1)),
        "weight_bytes_per_token": st.weight_bytes_per_token,
        "packed_param_bytes": st.packed_param_bytes,
        "dense_param_bytes": st.dense_param_bytes,
        "mean_ttft_seconds": float(np.nanmean([r.ttft for r in reqs])),
        # latency percentiles from the always-on ServeStats histograms
        # (exact at bench scale; NaN -> None so the JSON stays strict)
        "p50_ttft_seconds": _num(st.ttft_percentile(50)),
        "p99_ttft_seconds": _num(st.ttft_percentile(99)),
        "p50_decode_step_us": _num(1e6 * st.step_time_percentile(50)),
        "p99_decode_step_us": _num(1e6 * st.step_time_percentile(99)),
        "host_overhead_fraction": host_overhead,
        # full step-time histogram snapshot: roofline.py restores it to
        # compare measured step percentiles against the analytic model
        "decode_step_hist": _json_hist(st.step_time_hist.snapshot()),
        "kv_pool_bytes": sched.kv.pool_bytes(),
        "kv_paged": sched.kv.paged,
    }
    if sched.spec is not None:
        out.update(
            spec_k=sched.spec.k,
            spec_fused=sched.spec.fused,
            drafter=sched.drafter.kind,
            verify_steps=st.verify_steps,
            acceptance_rate=st.acceptance_rate,
            tokens_per_verify_step=st.tokens_per_verify_step,
            weight_bytes_per_accepted_token=st.weight_bytes_per_accepted_token,
            spec_draft_seconds=st.spec_draft_seconds,
            spec_dispatches=sched.telemetry.registry.counter(
                "serve_spec_dispatches").value,
        )
    return out


def _compile_counts(cfg, packed, rng, slots: int, max_seq: int) -> dict:
    """Distinct prefill jits for >= 8 distinct prompt lengths, exact vs
    bucketed admission. Arrivals are spaced so every request finds a free
    slot (groups of width 1): the count isolates the length axis."""
    from repro.serve import Request, SamplingParams, Scheduler

    lens = [5, 7, 9, 12, 16, 21, 30, 47]
    out = {}
    for mode, bucket in (("exact", False), ("bucketed", True)):
        sched = Scheduler(cfg, packed, max_slots=slots, max_seq=max_seq,
                          decode_chunk=4, page=PAGE, bucket=bucket)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab, (n,)).astype(np.int32),
                        params=SamplingParams(max_new_tokens=5), arrival=2 * i)
                for i, n in enumerate(lens)]
        sched.run(reqs)
        out[mode] = sched.telemetry.registry.counter("serve_prefill_traces").value
    out["distinct_lengths"] = len(lens)
    return out


def _baseline(path: str):
    """The committed benchmark JSON (pre-overwrite) as the floor baseline;
    None when absent or when ``REPRO_BENCH_NO_FLOORS`` is set (baseline
    regeneration mode)."""
    import os

    if os.environ.get("REPRO_BENCH_NO_FLOORS"):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _assert_serve_floors(report: dict, base: dict) -> None:
    """CI regression floors against the committed BENCH_serve.json.

    Throughput floors are generous (shared CI runners are noisy); byte
    accounting is deterministic for a fixed workload, so those floors are
    firm. A legitimate re-baseline regenerates the committed file with
    ``REPRO_BENCH_NO_FLOORS=1 python -m benchmarks.run serve``."""
    cont, bcont = report["continuous"], base["continuous"]
    assert cont["tokens_per_second"] >= 0.2 * bcont["tokens_per_second"], (
        f"serve throughput collapsed: {cont['tokens_per_second']:.1f} tok/s "
        f"vs committed {bcont['tokens_per_second']:.1f}")
    assert (cont["weight_bytes_per_token"]
            <= 1.01 * bcont["weight_bytes_per_token"]), (
        "weight bytes per decode token regressed vs the committed baseline")
    assert report["kv_pool"]["ratio"] <= base["kv_pool"]["ratio"] + 1e-6, (
        "paged/stripe KV pool byte ratio regressed")
    # host overhead is the async-admission win this bench pins: allow an
    # absolute noise margin over the committed value, never a collapse
    # back to synchronous-admission territory
    assert (cont["host_overhead_fraction"]
            <= bcont["host_overhead_fraction"] + 0.04), (
        f"host overhead fraction regressed: "
        f"{cont['host_overhead_fraction']:.3f} vs committed "
        f"{bcont['host_overhead_fraction']:.3f}")
    if "admission" in base:
        adm, badm = report["admission"], base["admission"]
        assert adm["async_vs_sync"] >= 0.8 * badm["async_vs_sync"], (
            f"async/sync admission throughput ratio collapsed: "
            f"{adm['async_vs_sync']:.2f} vs committed "
            f"{badm['async_vs_sync']:.2f}")
    if "packed_weights" in base:
        pw, bpw = report["packed_weights"], base["packed_weights"]
        assert (pw["packed"]["packed_param_bytes"]
                <= bpw["packed"]["packed_param_bytes"]), (
            "packed parameter footprint grew vs the committed baseline")
        assert (pw["packed"]["weight_bytes_per_token"]
                < pw["dense"]["weight_bytes_per_token"]), (
            "packed serving no longer beats dense on weight bytes/token")
    if "telemetry" in report:
        tele = report["telemetry"]
        assert tele["overhead_fraction"] <= tele["budget_fraction"], (
            f"telemetry-on decode throughput cost "
            f"{100 * tele['overhead_fraction']:.1f}% exceeds the "
            f"{100 * tele['budget_fraction']:.0f}% budget "
            f"(off={tele['off_decode_tokens_per_second']:.1f} tok/s, "
            f"on={tele['on_decode_tokens_per_second']:.1f} tok/s)")
    if "flightrec" in report:
        fr = report["flightrec"]
        assert fr["overhead_fraction"] <= fr["budget_fraction"], (
            f"flight-recorder decode throughput cost "
            f"{100 * fr['overhead_fraction']:.1f}% exceeds the "
            f"{100 * fr['budget_fraction']:.0f}% budget "
            f"(off={fr['off_decode_tokens_per_second']:.1f} tok/s, "
            f"rec={fr['rec_decode_tokens_per_second']:.1f} tok/s)")


def _assert_spec_floors(report: dict, base: dict) -> None:
    for name in ("ngram", "self_draft"):
        row, brow = report[name], base[name]
        assert row["tokens_per_second"] >= 0.2 * brow["tokens_per_second"], (
            f"spec {name} throughput collapsed vs committed baseline")
        assert (report["bytes_per_token_ratio"][name]
                <= base["bytes_per_token_ratio"][name] * 1.05), (
            f"spec {name} bytes/accepted-token ratio regressed")
    # the fused-loop floors: speculation must actually pay wall-clock on
    # the drafter-friendly workload, and fusing must beat the per-cycle
    # dispatch chain (the whole point of the scan)
    assert report["spec_speedup"]["ngram"] >= 1.0, (
        f"ngram speculation no longer beats the non-speculative baseline "
        f"wall-clock: {report['spec_speedup']['ngram']:.2f}x")
    assert report["fused_vs_unfused"]["ngram"] >= 1.0, (
        f"the fused spec loop no longer beats the unfused dispatch chain: "
        f"{report['fused_vs_unfused']['ngram']:.2f}x")


def run(out_path: str = "BENCH_serve.json") -> dict:
    from repro.configs.base import load_arch
    from repro.models import zoo
    from repro.train import pruning

    base = _baseline(out_path)

    cfg = load_arch("qwen2_0_5b").reduced(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=256, head_dim=32, max_seq=128)
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    _, _, packed, _ = pruning.prune_model(params, cfg, ocp_iters=2, icp_iters=2)

    slots, n_requests, prompt_len, max_seq = 4, 12, 12, 128
    results = {}
    for policy in ("static", "continuous"):
        reqs = _workload(cfg, np.random.default_rng(0), n_requests, slots, prompt_len)
        results[policy] = _serve(cfg, packed, reqs, policy, slots, max_seq,
                                 page=PAGE, n_pages=N_PAGES)

    # paged vs stripe: same continuous workload, pool memory + throughput
    reqs = _workload(cfg, np.random.default_rng(0), n_requests, slots, prompt_len)
    stripe = _serve(cfg, packed, reqs, "continuous", slots, max_seq, page=None)
    paged = results["continuous"]
    kv_ratio = paged["kv_pool_bytes"] / max(stripe["kv_pool_bytes"], 1)
    assert kv_ratio <= 0.5, (
        f"paged pool {paged['kv_pool_bytes']}B exceeds 0.5x the stripe pool "
        f"{stripe['kv_pool_bytes']}B at benchmark occupancy")

    # sharded decode: page-axis pool sharding over every visible device
    from repro import compat

    n_dev = len(jax.devices())
    mesh = compat.make_mesh((n_dev,), ("data",))
    reqs = _workload(cfg, np.random.default_rng(0), n_requests, slots, prompt_len)
    sharded = _serve(cfg, packed, reqs, "continuous", slots, max_seq,
                     page=PAGE, n_pages=N_PAGES, mesh=mesh)
    sharded["n_devices"] = n_dev
    sharded_vs_single = (sharded["tokens_per_second"]
                         / max(paged["tokens_per_second"], 1e-9))

    # async (double-buffered) vs synchronous admission: the continuous row
    # above already admits asynchronously ("auto" resolves on under the
    # continuous policy — prepare + prefill dispatch overlap the in-flight
    # decode chunk, the blocking first-token sync lands at the next step
    # boundary); this row pins what the overlap buys
    reqs = _workload(cfg, np.random.default_rng(0), n_requests, slots,
                     prompt_len)
    sync_row = _serve(cfg, packed, reqs, "continuous", slots, max_seq,
                      page=PAGE, n_pages=N_PAGES, async_admission=False)
    async_vs_sync = (paged["tokens_per_second"]
                     / max(sync_row["tokens_per_second"], 1e-9))

    # paged-attention kernel vs gather: the same continuous paged workload
    # with the decode attention resolved by the Pallas kernel vs the
    # pool[bt] gather path. Off-TPU the kernel runs under the Pallas
    # interpreter, so the step-time column is correctness-grade only
    # (interpreter overhead dominates); compiled numbers need a TPU.
    from repro.kernels.ops import _on_tpu
    from repro.perf_knobs import knobs

    kbackend = "pallas" if _on_tpu() else "interpret"
    n_kreq = 6
    with knobs(paged_attn="off"):
        kern_off = _serve(cfg, packed,
                          _workload(cfg, np.random.default_rng(2), n_kreq,
                                    slots, prompt_len),
                          "continuous", slots, max_seq,
                          page=PAGE, n_pages=N_PAGES)
    with knobs(paged_attn=kbackend):
        kern_on = _serve(cfg, packed,
                         _workload(cfg, np.random.default_rng(2), n_kreq,
                                   slots, prompt_len),
                         "continuous", slots, max_seq,
                         page=PAGE, n_pages=N_PAGES)
    kern_ratio = kern_on["decode_step_us"] / max(kern_off["decode_step_us"],
                                                 1e-9)

    # packed HiNM weights vs dense fallback: identical workload and
    # numerics (the fallback unpacks to masked-dense), so the bytes/token
    # column is the paper's packed-read saving and the latency column is
    # the backend's spmm-vs-dense cost on this host
    reqs = _workload(cfg, np.random.default_rng(0), n_requests, slots,
                     prompt_len)
    dense_row = _serve(cfg, packed, reqs, "continuous", slots, max_seq,
                       page=PAGE, n_pages=N_PAGES, packed="dense")
    packed_row = results["continuous"]  # params served packed as handed in
    assert packed_row["packed_param_bytes"] < dense_row["packed_param_bytes"], (
        "packed serving did not shrink the parameter footprint")
    assert (packed_row["weight_bytes_per_token"]
            < dense_row["weight_bytes_per_token"]), (
        "packed serving did not cut weight bytes per decode token")

    # telemetry overhead: the same continuous workload served with the
    # observability layer off vs fully on (wall-clock histograms + span
    # recording + KV gauges). Best-of-2 per mode damps runner noise; the
    # on-run's metrics snapshot and Chrome trace become the CI artifacts.
    # Sync admission here: with async admission the decode window absorbs
    # the overlapped admission work (prepare runs under the in-flight
    # chunk, which on a shared-core CPU runner is real contention), and
    # how admissions interleave varies run to run — that variance would
    # swamp the 3% budget this compare isolates. The async columns live
    # in report["admission"].
    from repro.serve import Telemetry

    tele_rows = {}
    tele_bundles = []
    for mode in ("off", "on", "rec"):
        # "rec": flight recorder on with telemetry off — the recorder is
        # off by default in production, and this isolates its own decode
        # cost (one event dict per host decision) under the same budget
        best = None
        for _ in range(2):
            tele = Telemetry(enabled=(mode == "on"))
            row = _serve(cfg, packed,
                         _workload(cfg, np.random.default_rng(0), n_requests,
                                   slots, prompt_len),
                         "continuous", slots, max_seq,
                         page=PAGE, n_pages=N_PAGES, telemetry=tele,
                         async_admission=False, flightrec=(mode == "rec"))
            if best is None or (row["decode_tokens_per_second"]
                                > best["decode_tokens_per_second"]):
                best = row
                if mode == "on":
                    tele_bundles = [tele]
        tele_rows[mode] = best
    tele_overhead = max(0.0, 1.0 - (tele_rows["on"]["decode_tokens_per_second"]
                                    / max(tele_rows["off"]["decode_tokens_per_second"],
                                          1e-9)))
    rec_overhead = max(0.0, 1.0 - (tele_rows["rec"]["decode_tokens_per_second"]
                                   / max(tele_rows["off"]["decode_tokens_per_second"],
                                         1e-9)))
    tele_bundles[0].dump_metrics("BENCH_serve_metrics.json")
    tele_bundles[0].dump_trace("BENCH_serve_trace.json")

    # record + replay: the recorder's determinism contract on the bench
    # workload — rebuilding the workload from the record and re-driving a
    # fresh scheduler must reproduce every event and every token; the
    # record ships as a CI artifact next to the metrics/trace dumps
    from repro.serve import Scheduler
    from repro.serve import replay as replay_record

    rec_kw = dict(max_slots=slots, max_seq=max_seq, decode_chunk=4,
                  policy="continuous", page=PAGE, n_pages=N_PAGES,
                  flightrec=True)
    rec_sched = Scheduler(cfg, packed, **rec_kw)
    rec_sched.run(_workload(cfg, np.random.default_rng(0), n_requests,
                            slots, prompt_len))
    rec_sched.flight.dump("BENCH_serve_flightrec.jsonl")
    replay_record("BENCH_serve_flightrec.jsonl",
                  Scheduler(cfg, packed, **rec_kw)).assert_equal()

    compiles = _compile_counts(cfg, packed, np.random.default_rng(1), 8, max_seq)
    assert compiles["bucketed"] <= 4, (
        f"{compiles['distinct_lengths']} prompt lengths compiled "
        f"{compiles['bucketed']} bucketed prefill variants (> 4)")

    speedup = (results["continuous"]["tokens_per_second"]
               / max(results["static"]["tokens_per_second"], 1e-9))
    step_ratio = (results["static"]["decode_steps"]
                  / max(results["continuous"]["decode_steps"], 1))
    report = {
        "shape": {"arch": "qwen2_0_5b.reduced", "d_model": cfg.d_model,
                  "n_layers": cfg.n_layers, "vocab": cfg.vocab,
                  "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
                  "head_dim": cfg.head_dim, "d_ff": cfg.d_ff,
                  "max_seq": max_seq, "page": PAGE,
                  "slots": slots, "n_requests": n_requests,
                  "prompt_len": prompt_len},
        "static": results["static"],
        "continuous": results["continuous"],
        "throughput_speedup": speedup,
        "decode_step_ratio": step_ratio,
        "stripe_continuous": stripe,
        "kv_pool": {
            "page": PAGE,
            "n_pages": N_PAGES,
            "paged_bytes": paged["kv_pool_bytes"],
            "stripe_bytes": stripe["kv_pool_bytes"],
            "ratio": kv_ratio,
        },
        "prefill_compiles": compiles,
        "paged_attn_kernel": {
            "backend": kbackend,
            "timing_grade": ("compiled" if kbackend == "pallas"
                             else "interpreter-correctness-only"),
            "gather": {k: kern_off[k] for k in
                       ("decode_step_us", "decode_tokens_per_second",
                        "tokens_per_second")},
            "kernel": {k: kern_on[k] for k in
                       ("decode_step_us", "decode_tokens_per_second",
                        "tokens_per_second")},
            "kernel_vs_gather_step_time": kern_ratio,
        },
        "packed_weights": {
            "packed": {k: packed_row[k] for k in
                       ("packed_param_bytes", "dense_param_bytes",
                        "weight_bytes_per_token", "tokens_per_second",
                        "decode_step_us")},
            "dense": {k: dense_row[k] for k in
                      ("packed_param_bytes", "weight_bytes_per_token",
                       "tokens_per_second", "decode_step_us")},
            "bytes_per_token_ratio": (packed_row["weight_bytes_per_token"]
                                      / dense_row["weight_bytes_per_token"]),
        },
        "sharded": {
            "n_devices": n_dev,
            "tokens_per_second": sharded["tokens_per_second"],
            "single_device_tokens_per_second": paged["tokens_per_second"],
            "vs_single_device": sharded_vs_single,
            "kv_pool_bytes": sharded["kv_pool_bytes"],
        },
        "admission": {
            "async": {k: paged[k] for k in
                      ("tokens_per_second", "host_overhead_fraction",
                       "mean_ttft_seconds")},
            "sync": {k: sync_row[k] for k in
                     ("tokens_per_second", "host_overhead_fraction",
                      "mean_ttft_seconds")},
            "async_vs_sync": async_vs_sync,
        },
        "telemetry": {
            "off_decode_tokens_per_second":
                tele_rows["off"]["decode_tokens_per_second"],
            "on_decode_tokens_per_second":
                tele_rows["on"]["decode_tokens_per_second"],
            "overhead_fraction": tele_overhead,
            "budget_fraction": 0.03,
            "p50_ttft_seconds": tele_rows["on"]["p50_ttft_seconds"],
            "p99_ttft_seconds": tele_rows["on"]["p99_ttft_seconds"],
            "p99_decode_step_us": tele_rows["on"]["p99_decode_step_us"],
            "host_overhead_fraction":
                tele_rows["on"]["host_overhead_fraction"],
            "artifacts": ["BENCH_serve_metrics.json",
                          "BENCH_serve_trace.json"],
        },
        "flightrec": {
            "off_decode_tokens_per_second":
                tele_rows["off"]["decode_tokens_per_second"],
            "rec_decode_tokens_per_second":
                tele_rows["rec"]["decode_tokens_per_second"],
            "overhead_fraction": rec_overhead,
            "budget_fraction": 0.03,
            "events": rec_sched.flight.seq,
            "replay_ok": True,  # assert_equal above would have raised
            "artifacts": ["BENCH_serve_flightrec.jsonl"],
        },
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)

    for policy in ("static", "continuous"):
        r = results[policy]
        emit(f"serve_{policy}", r["makespan_seconds"] * 1e6 / max(r["tokens"], 1),
             f"tok/s={r['tokens_per_second']:.1f} steps={r['decode_steps']}")
    emit("serve_speedup", 0.0,
         f"continuous/static={speedup:.2f}x step_ratio={step_ratio:.2f}x")
    emit("serve_paged_pool", 0.0,
         f"paged/stripe_bytes={kv_ratio:.3f} "
         f"paged_tok/s={paged['tokens_per_second']:.1f} "
         f"stripe_tok/s={stripe['tokens_per_second']:.1f}")
    emit("serve_prefill_compiles", 0.0,
         f"exact={compiles['exact']} bucketed={compiles['bucketed']} "
         f"lengths={compiles['distinct_lengths']}")
    emit("serve_sharded", 0.0,
         f"devices={n_dev} tok/s={sharded['tokens_per_second']:.1f} "
         f"vs_single={sharded_vs_single:.2f}x")
    emit("serve_admission", 0.0,
         f"async_tok/s={paged['tokens_per_second']:.1f} "
         f"sync_tok/s={sync_row['tokens_per_second']:.1f} "
         f"async_vs_sync={async_vs_sync:.2f}x "
         f"host_overhead={paged['host_overhead_fraction']:.3f}"
         f"(sync={sync_row['host_overhead_fraction']:.3f})")
    emit("serve_paged_attn", kern_on["decode_step_us"],
         f"backend={kbackend} gather_step_us={kern_off['decode_step_us']:.0f} "
         f"kernel_step_us={kern_on['decode_step_us']:.0f} "
         f"kernel/gather={kern_ratio:.2f}x")
    emit("serve_packed_weights", packed_row["decode_step_us"],
         f"bytes/tok packed={packed_row['weight_bytes_per_token']:.0f} "
         f"dense={dense_row['weight_bytes_per_token']:.0f} "
         f"packed_tok/s={packed_row['tokens_per_second']:.1f} "
         f"dense_tok/s={dense_row['tokens_per_second']:.1f}")
    r = results["continuous"]
    emit("serve_latency", 0.0,
         f"p50_ttft_ms={1e3 * (r['p50_ttft_seconds'] or 0):.1f} "
         f"p99_ttft_ms={1e3 * (r['p99_ttft_seconds'] or 0):.1f} "
         f"p99_step_us={r['p99_decode_step_us'] or 0:.0f} "
         f"host_overhead={r['host_overhead_fraction']:.3f}")
    emit("serve_telemetry", 0.0,
         f"off_tok/s={tele_rows['off']['decode_tokens_per_second']:.1f} "
         f"on_tok/s={tele_rows['on']['decode_tokens_per_second']:.1f} "
         f"overhead={tele_overhead:.4f} budget=0.03")
    emit("serve_flightrec", 0.0,
         f"off_tok/s={tele_rows['off']['decode_tokens_per_second']:.1f} "
         f"rec_tok/s={tele_rows['rec']['decode_tokens_per_second']:.1f} "
         f"overhead={rec_overhead:.4f} budget=0.03 "
         f"events={rec_sched.flight.seq} replay=ok")
    if base is not None:
        _assert_serve_floors(report, base)
    return report


def run_spec(out_path: str = "BENCH_spec.json") -> dict:
    """Speculative decoding vs the chunked baseline (`BENCH_spec.json`).

    A repetitive-prompt workload (a 4-token pattern tiled, the generation
    itself settles into loops a prompt-lookup drafter can predict) decoded
    four ways on the same paged pool: non-speculative baseline, n-gram
    drafter through the fused draft/verify scan, the same drafter through
    the unfused per-cycle dispatch chain, and a self-drafting ModelDrafter
    (draft == target, the acceptance-1.0 upper bound that pins the stats
    algebra).  CI asserts: tokens identical to the baseline, acceptance-
    weighted tokens-per-verify-step > 1 for the drafters, a proportional
    drop in packed-weight bytes per accepted token, and (vs the committed
    baseline) the wall-clock floors `ngram >= 1.0x baseline` and
    `fused >= unfused`."""
    import jax

    from repro.configs.base import load_arch
    from repro.models import zoo
    from repro.serve import (ModelDrafter, Request, SamplingParams, Scheduler,
                             SpecConfig)
    from repro.train import pruning

    base = _baseline(out_path)
    cfg = load_arch("qwen2_0_5b").reduced(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=256, head_dim=32, max_seq=128)
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    _, _, packed, _ = pruning.prune_model(params, cfg, ocp_iters=2, icp_iters=2)

    # 64 new tokens per request: long enough that the generation's
    # repetitive steady-state (which the prompt-lookup drafter predicts
    # well) dominates the low-acceptance warmup tokens — the wall-clock
    # floor `ngram >= baseline` is measured where speculation should win
    slots, n_requests, max_new, max_seq, k = 4, 8, 64, 128, 4
    rng = np.random.default_rng(0)
    pat = rng.integers(0, cfg.vocab, (4,)).astype(np.int32)

    def workload():
        return [Request(rid=i,
                        prompt=np.tile(np.roll(pat, i % 4), 6).astype(np.int32),
                        params=SamplingParams(max_new_tokens=max_new),
                        arrival=i)
                for i in range(n_requests)]

    def case(spec, runs=2):
        # best-of-N damps runner noise on the wall-clock columns the
        # fused-vs-unfused and spec-vs-baseline floors compare; tokens
        # must not move between repeats (greedy = deterministic)
        best, toks = None, None
        for _ in range(runs):
            reqs = workload()
            # sharing off: the tiled prompts repeat across requests, and a
            # prefix hit would shrink the prefill this benchmark isolates
            # speculation against (run_replay owns the sharing columns)
            sched = Scheduler(cfg, packed, max_slots=slots, max_seq=max_seq,
                              decode_chunk=4, page=PAGE, n_pages=24,
                              spec=spec, prefix_share=False)
            row = _drive(sched, reqs)
            t = [r.tokens for r in reqs]
            assert toks is None or t == toks
            toks = t
            if best is None or (row["tokens_per_second"]
                                > best["tokens_per_second"]):
                best = row
        return best, toks

    base_row, base_toks = case(None)
    ngram_row, ngram_toks = case(SpecConfig(k=k, drafter="ngram"))
    unfused_row, unfused_toks = case(
        SpecConfig(k=k, drafter="ngram", fused=False))
    self_row, self_toks = case(
        SpecConfig(k=k, drafter=ModelDrafter(cfg, packed)), runs=1)

    # the serving contract survives speculation: tokens are identical
    assert ngram_toks == base_toks, "ngram spec decode changed tokens"
    assert unfused_toks == base_toks, "unfused spec decode changed tokens"
    assert self_toks == base_toks, "self-draft spec decode changed tokens"
    # the fused scan actually fused (one dispatch per step, covering all
    # of that step's cycles) and the unfused chain actually did not
    assert ngram_row["spec_dispatches"] < ngram_row["verify_steps"]
    assert unfused_row["spec_dispatches"] >= 2 * unfused_row["verify_steps"]
    assert ngram_row["spec_draft_seconds"] == 0.0
    assert unfused_row["spec_draft_seconds"] > 0.0
    # acceptance-weighted tokens per verify must beat 1 (else speculation
    # never pays), and the packed-weight read per accepted token must drop
    # proportionally vs the baseline's per-chunk-step read
    for row in (ngram_row, self_row):
        assert row["tokens_per_verify_step"] > 1.0, row
        assert (row["weight_bytes_per_accepted_token"]
                < base_row["weight_bytes_per_token"]), row
    assert self_row["acceptance_rate"] == 1.0  # draft == target upper bound

    report = {
        "shape": {"arch": "qwen2_0_5b.reduced", "d_model": cfg.d_model,
                  "n_layers": cfg.n_layers, "vocab": cfg.vocab,
                  "slots": slots, "n_requests": n_requests,
                  "max_new_tokens": max_new, "spec_k": k},
        "baseline": base_row,
        "ngram": ngram_row,
        "ngram_unfused": unfused_row,
        "self_draft": self_row,
        "bytes_per_token_ratio": {
            "ngram": (ngram_row["weight_bytes_per_accepted_token"]
                      / base_row["weight_bytes_per_token"]),
            "self_draft": (self_row["weight_bytes_per_accepted_token"]
                           / base_row["weight_bytes_per_token"]),
        },
        # wall-clock, not bytes: speculation vs the non-speculative
        # baseline, and the fused scan vs the per-cycle dispatch chain
        "spec_speedup": {
            "ngram": (ngram_row["tokens_per_second"]
                      / max(base_row["tokens_per_second"], 1e-9)),
            "self_draft": (self_row["tokens_per_second"]
                           / max(base_row["tokens_per_second"], 1e-9)),
        },
        "fused_vs_unfused": {
            "ngram": (ngram_row["tokens_per_second"]
                      / max(unfused_row["tokens_per_second"], 1e-9)),
        },
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)

    for name, row in (("baseline", base_row), ("ngram", ngram_row),
                      ("ngram_unfused", unfused_row),
                      ("self_draft", self_row)):
        tps = row.get("tokens_per_verify_step", 1.0)
        acc = row.get("acceptance_rate", 0.0)
        emit(f"serve_spec_{name}",
             row["makespan_seconds"] * 1e6 / max(row["tokens"], 1),
             f"tok/s={row['tokens_per_second']:.1f} "
             f"tok/verify={tps:.2f} accept={acc:.3f} "
             f"bytes/tok={row.get('weight_bytes_per_accepted_token', row['weight_bytes_per_token']):.0f}")
    emit("serve_spec_fusion", 0.0,
         f"ngram_vs_baseline={report['spec_speedup']['ngram']:.2f}x "
         f"fused_vs_unfused={report['fused_vs_unfused']['ngram']:.2f}x "
         f"fused_dispatches={ngram_row['spec_dispatches']} "
         f"unfused_dispatches={unfused_row['spec_dispatches']} "
         f"cycles={ngram_row['verify_steps']}")
    if base is not None:
        _assert_spec_floors(report, base)
    return report


def _replay_workload(cfg, scale: float):
    """Deterministic Poisson-arrival multi-tenant mix: `n_short` short
    completions over one shared system prefix (two full pages + a shared
    tail -> full-page hits and CoW), plus a few long unshared requests
    whose monolithic prefill would block co-resident decode."""
    from repro.serve import Request, SamplingParams

    rng = np.random.default_rng(7)
    n_short = max(4, int(12 * scale))
    n_long = max(1, int(3 * scale))
    # shorts must decode long enough to overlap (arrival gap ~1.7 steps):
    # only CO-RESIDENT sharers shrink live pages — a lone sharer still
    # maps pages_needed(reserve) pages, just prefills fewer rows
    short_new = max(12, int(24 * scale))
    long_new = max(8, int(16 * scale))
    system = rng.integers(0, cfg.vocab, (2 * PAGE + 8,)).astype(np.int32)
    reqs = []
    for i in range(n_short + n_long):
        if i % ((n_short + n_long) // n_long + 1) == 0 and n_long > 0:
            prompt = rng.integers(0, cfg.vocab, (3 * PAGE,)).astype(np.int32)
            new = long_new
        else:
            # tail long enough that page 2 (system tail rows + private
            # suffix) fills -> indexed -> later twins CoW its shared head
            tail = rng.integers(0, cfg.vocab, (10,)).astype(np.int32)
            prompt = np.concatenate([system, tail])
            new = short_new
        reqs.append(Request(rid=i, prompt=prompt,
                            params=SamplingParams(max_new_tokens=new)))
    # Poisson process in scheduler steps: geometric inter-arrival gaps
    gaps = rng.geometric(0.6, size=len(reqs))
    arrivals = np.cumsum(gaps) - gaps[0]
    order = rng.permutation(len(reqs))
    for r, t in zip(reqs, arrivals[np.argsort(order)]):
        r.arrival = int(t)
    return reqs


def _drive_replay(sched, reqs):
    """Step the scheduler manually so pool occupancy can be sampled at
    every step.  Occupancy counts LIVE pages — distinct pages mapped by
    resident slots' block tables; retained prefix pages are reclaimable
    cache (evicted under pressure), not demand, so counting them would
    charge the cache for existing.  `live_page_steps` integrates live
    pages over the whole replay (page-steps): sharing shrinks it even
    when the single peak step happens to be dominated by unshared longs."""
    pending = sorted(reqs, key=lambda r: (r.arrival, r.rid))
    peak_pages, page_steps, t = 0, 0, 0
    max_prefill_rows_step, step_walls = 0, []
    t0 = time.perf_counter()
    while pending or sched.n_pending:
        while pending and pending[0].arrival <= t:
            sched.submit(pending.pop(0))
        rows_before = sched.stats.prefill_rows
        s0 = time.perf_counter()
        sched.step()
        step_walls.append(time.perf_counter() - s0)
        max_prefill_rows_step = max(
            max_prefill_rows_step, sched.stats.prefill_rows - rows_before)
        live = sched.kv.n_live_pages
        peak_pages = max(peak_pages, live)
        page_steps += live
        t += 1
    makespan = time.perf_counter() - t0
    st = sched.stats
    return {
        "tokens": st.tokens_generated,
        "requests": st.requests_finished,
        "makespan_seconds": makespan,
        "goodput_tokens_per_second": st.tokens_generated / max(makespan, 1e-9),
        "prefix_hit_tokens": st.prefix_hit_tokens,
        "prefill_rows": st.prefill_rows,
        "prefill_chunks": st.prefill_chunks,
        "prefix_hit_rate": st.prefix_hit_rate,
        "peak_live_pages": peak_pages,
        "live_page_steps": page_steps,
        "max_prefill_rows_step": max_prefill_rows_step,
        "p99_step_seconds": _num(np.percentile(step_walls, 99)),
        "pool_pages": sched.kv.n_alloc_pages,
        "cow_copies": sched.kv.cow_copies,
        "p50_ttft_seconds": _num(st.ttft_percentile(50)),
        "p99_ttft_seconds": _num(st.ttft_percentile(99)),
        "prefix_share": sched.prefix_share,
        "prefill_chunk": sched.prefill_chunk,
    }


def _assert_replay_floors(report: dict, base: dict) -> None:
    """Floors vs the committed BENCH_serve_replay.json: admission order is
    deterministic, so the sharing/memory columns get firm floors; only
    wall-clock goodput gets the generous noisy-runner margin."""
    row, brow = report["sharing"], base["sharing"]
    assert (row["goodput_tokens_per_second"]
            >= 0.2 * brow["goodput_tokens_per_second"]), (
        "replay goodput collapsed vs the committed baseline")
    assert row["prefix_hit_rate"] >= brow["prefix_hit_rate"] - 1e-6, (
        f"prefix hit rate regressed: {row['prefix_hit_rate']:.3f} vs "
        f"committed {brow['prefix_hit_rate']:.3f}")
    assert (report["live_pages_ratio"]
            <= base["live_pages_ratio"] + 1e-6), (
        "sharing/no-sharing live page occupancy ratio regressed")
    assert (report["prefill_rows_ratio"]
            <= base["prefill_rows_ratio"] + 1e-6), (
        "sharing/no-sharing prefill compute ratio regressed")


def run_replay(out_path: str = "BENCH_serve_replay.json") -> dict:
    import os

    from repro import compat
    from repro.configs.base import load_arch
    from repro.models import zoo
    from repro.train import pruning

    base = _baseline(out_path)
    scale = float(os.environ.get("REPRO_BENCH_REPLAY_SCALE", "1.0"))

    cfg = load_arch("qwen2_0_5b").reduced(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=256, head_dim=32, max_seq=128)
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    _, _, packed, _ = pruning.prune_model(params, cfg, ocp_iters=2, icp_iters=2)

    slots, max_seq, n_pages = 4, 128, 20
    chunk = PAGE

    def case(mesh=None, **sched_kw):
        from repro.serve import Scheduler

        sched = Scheduler(cfg, packed, max_slots=slots, max_seq=max_seq,
                          decode_chunk=4, page=PAGE, n_pages=n_pages,
                          mesh=mesh, **sched_kw)
        # warm the decode/prefill/extension jits outside the timed region
        warm = _replay_workload(cfg, scale)
        for r in warm:
            r.arrival = 0
        sched.run(warm)
        sched.reset()
        return _drive_replay(sched, _replay_workload(cfg, scale))

    rows = {
        "no_sharing": case(prefix_share=False),
        "sharing": case(prefix_share=True),
        "sharing_chunked": case(prefix_share=True, prefill_chunk=chunk),
    }
    n_dev = len(jax.devices())
    rows["sharing_sharded"] = case(
        mesh=compat.make_mesh((n_dev,), ("data",)), prefix_share=True)
    rows["sharing_sharded"]["n_devices"] = n_dev

    # the workload shares by construction: the sharing run must hit, copy
    # divergent tails, and strictly cut both live page occupancy and
    # prefill compute vs the identical no-sharing replay (deterministic
    # admission)
    share, nosh = rows["sharing"], rows["no_sharing"]
    assert share["prefix_hit_rate"] > 0, "replay workload never hit"
    assert share["cow_copies"] > 0, "replay workload never exercised CoW"
    pages_ratio = share["live_page_steps"] / max(nosh["live_page_steps"], 1)
    rows_ratio = share["prefill_rows"] / max(nosh["prefill_rows"], 1)
    assert pages_ratio < 1.0, (
        f"sharing did not reduce live page occupancy: "
        f"{share['live_page_steps']} page-steps "
        f"vs {nosh['live_page_steps']} unshared")
    assert rows_ratio < 1.0, (
        f"sharing did not reduce prefill compute: {share['prefill_rows']} "
        f"rows vs {nosh['prefill_rows']} unshared")
    # chunking bounds per-step prefill work (the co-resident latency
    # spike), deterministically: each mid-prefill slot advances at most
    # `chunk` rows per step (the batched advance covers every prefilling
    # slot, so the aggregate bound is chunk * slots), vs the unchunked
    # run's monolithic long prefills.  The chunked request's OWN first
    # token arrives later by construction (its prefill interleaves with
    # decode chunks), so p99 TTFT is reported, not asserted — the
    # latency-shape win is the per-step bound.
    chunked = rows["sharing_chunked"]
    assert chunked["prefill_chunks"] > 0
    assert chunked["max_prefill_rows_step"] <= chunk * slots, (
        f"chunked prefill exceeded the per-step bound: "
        f"{chunked['max_prefill_rows_step']} rows > "
        f"chunk*slots={chunk * slots}")
    assert (chunked["max_prefill_rows_step"]
            < share["max_prefill_rows_step"]), (
        "chunking did not shrink the worst per-step prefill burst: "
        f"{chunked['max_prefill_rows_step']} vs "
        f"{share['max_prefill_rows_step']} unchunked")
    assert rows["sharing_sharded"]["prefix_hit_rate"] > 0

    report = {
        "shape": {"arch": "qwen2_0_5b.reduced", "d_model": cfg.d_model,
                  "n_layers": cfg.n_layers, "vocab": cfg.vocab,
                  "max_seq": max_seq, "page": PAGE, "n_pages": n_pages,
                  "slots": slots, "prefill_chunk": chunk,
                  "replay_scale": scale,
                  "n_requests": len(_replay_workload(cfg, scale))},
        **rows,
        "live_pages_ratio": pages_ratio,
        "prefill_rows_ratio": rows_ratio,
        "chunked_vs_unchunked_p99_ttft": (
            (rows["sharing_chunked"]["p99_ttft_seconds"] or 0)
            / max(share["p99_ttft_seconds"] or 1e-9, 1e-9)),
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)

    for name, row in rows.items():
        emit(f"serve_replay_{name}",
             row["makespan_seconds"] * 1e6 / max(row["tokens"], 1),
             f"goodput={row['goodput_tokens_per_second']:.1f}tok/s "
             f"hit_rate={row['prefix_hit_rate']:.3f} "
             f"peak_pages={row['peak_live_pages']} "
             f"prefill_rows={row['prefill_rows']} "
             f"p50_ttft_ms={1e3 * (row['p50_ttft_seconds'] or 0):.1f} "
             f"p99_ttft_ms={1e3 * (row['p99_ttft_seconds'] or 0):.1f}")
    emit("serve_replay_sharing", 0.0,
         f"pages_ratio={pages_ratio:.3f} prefill_rows_ratio={rows_ratio:.3f} "
         f"cow={share['cow_copies']} "
         f"max_step_rows={share['max_prefill_rows_step']}"
         f"->{chunked['max_prefill_rows_step']}chunked "
         f"chunked_p99_ttft_ratio={report['chunked_vs_unchunked_p99_ttft']:.2f}")
    if base is not None:
        _assert_replay_floors(report, base)
    return report


if __name__ == "__main__":
    run()
    run_spec()
    run_replay()
