"""PermGraph offline-search wall-clock: serial walker-equivalent vs
thread-pool node dispatch vs warm saliency-hash cache.

The old walker ran every layer's searches strictly serially; the PermGraph
engine dispatches independent (container, layer, node) items over a thread
pool and memoizes search results by saliency hash. This entry times a
multi-layer `prune_model` three ways and reports the speedups.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs.base import ArchConfig
from repro.core.types import HiNMConfig
from repro.models import zoo
from repro.perm import PermCache
from repro.train import pruning


def _cfg() -> ArchConfig:
    # projections big enough that the jit'd cost evals (GIL-released XLA
    # compute) dominate Python dispatch — the regime real models are in
    return ArchConfig(
        name="bench", family="dense", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=512, head_dim=64, max_seq=64,
        dtype=jnp.float32, hinm=HiNMConfig(v=32, n=2, m=4, vector_sparsity=0.5),
    )


def _time_prune(params, cfg, **kw) -> float:
    t0 = time.perf_counter()
    pruning.prune_model(params, cfg, ocp_iters=3, icp_iters=3, **kw)
    return time.perf_counter() - t0


def run() -> None:
    cfg = _cfg()
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    # warm jit caches so the serial baseline isn't charged compile time
    pruning.prune_model(params, cfg, ocp_iters=1, icp_iters=1, workers=1)

    serial = _time_prune(params, cfg, workers=1)
    workers = max(2, min(8, os.cpu_count() or 2))
    parallel = _time_prune(params, cfg, workers=workers)

    cache = PermCache()
    _time_prune(params, cfg, workers=workers, cache=cache)      # fill
    warm = _time_prune(params, cfg, workers=workers, cache=cache)

    emit("permgraph_search_serial", serial * 1e6, "1 worker")
    emit("permgraph_search_parallel", parallel * 1e6,
         f"{workers} workers speedup={serial / parallel:.2f}x")
    emit("permgraph_search_warm_cache", warm * 1e6,
         f"speedup={serial / warm:.2f}x")


if __name__ == "__main__":
    run()
