"""Figures 3/4 analogue — one-shot pruning quality vs sparsity.

Per sparsity in {65, 75, 85}%, reports retained-saliency fraction for
  HiNM (gyro) / HiNM-NoPerm / OVW (vector-only + k-means OCP) /
  Unstructured (upper bound),
on ResNet-shaped conv weights (flattened to (C_out, C_in*k*k), magnitude
saliency — the paper's CNN setting, V=32).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, structured_weights, time_us
from repro.core import baselines
from repro.core.gyro import gyro_permute
from repro.core.types import HiNMConfig

# (C_out, C_in*k*k) for representative ResNet18/50 conv layers
SHAPES = [(128, 1152), (256, 2304)]
SPARSITIES = [0.65, 0.75, 0.85]


def vector_sparsity_for(total: float, n: int = 2, m: int = 4) -> float:
    """total = 1 - (1-sv) * N/M  ->  sv."""
    return 1.0 - (1.0 - total) * m / n


def run() -> None:
    rng = np.random.default_rng(0)
    for total in SPARSITIES:
        sv = vector_sparsity_for(total)
        cfg = HiNMConfig(v=32, n=2, m=4, vector_sparsity=sv)
        fr = {"hinm": [], "noperm": [], "ovw": [], "unstructured": []}
        t_gyro = 0.0
        for shape in SHAPES:
            sal = np.abs(structured_weights(rng, *shape))
            import time as _t

            t0 = _t.perf_counter()
            gy = gyro_permute(sal, cfg, ocp_iters=10, icp_iters=8,
                              rng=np.random.default_rng(1))
            t_gyro += (_t.perf_counter() - t0) * 1e6
            nop = gyro_permute(sal, cfg, rng=np.random.default_rng(1),
                               run_ocp=False, run_icp=False)
            fr["hinm"].append(gy.retained_fraction)
            fr["noperm"].append(nop.retained_fraction)
            fr["ovw"].append(baselines.ovw_prune(sal, 32, total,
                                                 np.random.default_rng(1)))
            fr["unstructured"].append(baselines.unstructured_retained(sal, total))
        for k, v in fr.items():
            emit(f"fig3_oneshot_{int(total*100)}pct_{k}", t_gyro / len(SHAPES),
                 f"retained_frac={np.mean(v):.4f}")


if __name__ == "__main__":
    run()
