import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis per (architecture x shape) on the single-pod mesh.

  PYTHONPATH=src python -m benchmarks.roofline [--arch A] [--shape S]

Methodology (EXPERIMENTS.md §Roofline): XLA's cost_analysis counts a
while-loop body once, so raw dry-run numbers under-count layer-scanned
models. We therefore compile *cost probes* — reduced-depth configs with
every loop unrolled (repro.models.probe_mode) — at two depths and
extrapolate linearly in layers (and bilinearly in sequence length for the
time-recurrent xlstm cells). Collective bytes come from parsing the
probes' partitioned HLO (per-device output shapes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

  compute_term    = flops_per_device / 197e12
  memory_term     = bytes_per_device / 819e9
  collective_term = collective_bytes_per_device / 50e9

MODEL_FLOPS = 6*N*D (train) or 2*N*D (serve), N = matmul params
(embedding excluded; MoE scaled by top_k/E; serve path additionally scaled
by the HiNM vector-sparsity FLOP saving on pruned projections).
"""

import argparse
import dataclasses
import json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _probe_stats(cfg, shape_name, mesh, shape_override=None):
    import jax

    from repro.launch import cells as cell_lib
    from repro.launch import hlo_stats
    from repro.models import probe_mode

    with probe_mode.cost_probe():
        cell = cell_lib.build_cell(cfg, shape_name, mesh, shape_override)
        lowered = cell_lib.lower_cell(cell, mesh)
        compiled = lowered.compile()
    cs = hlo_stats.cost_summary(compiled)
    coll = hlo_stats.collective_bytes(compiled.as_text())
    return {
        "flops": cs["flops_per_device"],
        "bytes": cs["bytes_accessed_per_device"],
        "coll": float(coll["total_bytes"]),
        "coll_by_kind": coll["bytes"],
    }


def _period(cfg) -> int:
    if cfg.family in ("hybrid", "ssm") and cfg.block_pattern:
        return len(cfg.block_pattern)
    return 1


def _probe_cfg(cfg, n_layers):
    kw = {"n_layers": n_layers}
    if cfg.family == "encdec":
        kw["n_enc_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


def extrapolated_cell_stats(cfg, shape_name, mesh):
    """Probe-compile at two depths (and two seq lens for ssm train/prefill)
    and extrapolate to the full config. Returns per-device stats dict."""
    from repro.configs.base import SHAPES

    seq, batch, kind = SHAPES[shape_name]
    p = _period(cfg)
    l_full = cfg.n_layers

    time_recurrent = cfg.family == "ssm" and kind in ("train", "prefill")
    if time_recurrent:
        # tiny probe sequences: the unrolled per-timestep cost is
        # S-independent, and larger S makes the unrolled-HLO compile blow up
        s1, s2 = 16, 32
        f = {}
        for li, l in ((1, p), (2, 2 * p)):
            for si, s in ((1, s1), (2, s2)):
                f[(li, si)] = _probe_stats(_probe_cfg(cfg, l), shape_name, mesh,
                                           shape_override=(s, batch))

        def bilinear(key):
            f11, f21 = f[(1, 1)][key], f[(2, 1)][key]
            f12, f22 = f[(1, 2)][key], f[(2, 2)][key]
            # F = c0 + c1*L + c2*S + c3*L*S  solved on the 2x2 probe grid
            c3 = (f22 - f21 - f12 + f11) / ((2 * p - p) * (s2 - s1))
            c1 = (f21 - f11) / (2 * p - p) - c3 * s1
            c2 = (f12 - f11) / (s2 - s1) - c3 * p
            c0 = f11 - c1 * p - c2 * s1 - c3 * p * s1
            return c0 + c1 * l_full + c2 * seq + c3 * l_full * seq

        return {k: bilinear(k) for k in ("flops", "bytes", "coll")}

    f1 = _probe_stats(_probe_cfg(cfg, p), shape_name, mesh)
    f2 = _probe_stats(_probe_cfg(cfg, 2 * p), shape_name, mesh)

    def linear(key):
        per_period = f2[key] - f1[key]
        return f1[key] + per_period * (l_full / p - 1)

    return {k: linear(k) for k in ("flops", "bytes", "coll")}


def model_flops(cfg, shape_name) -> float:
    """Ideal useful FLOPs for the cell (global, per step)."""
    import jax
    import numpy as np

    from repro.configs.base import SHAPES
    from repro.models import zoo
    from repro.train.abstract import _planned_paths, _get_container
    from repro.models import module as mnn

    seq, batch, kind = SHAPES[shape_name]
    pshape = jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0), cfg))

    flat, _ = jax.tree_util.tree_flatten_with_path(pshape)
    n_total = 0
    for pathkeys, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "name", k))) for k in pathkeys)
        if "embed/table" in path or leaf.ndim < 2:
            continue
        n = int(np.prod(leaf.shape))
        if "/moe/" in path and cfg.n_experts:
            n = n * cfg.top_k // cfg.n_experts
        n_total += n

    # serve path: pruned projections contract only K of n_in columns
    if kind in ("prefill", "decode"):
        pruned = 0
        for key, sel, spec in _planned_paths(cfg):
            node = mnn.get_path(_get_container(pshape, key, sel), spec.path)
            n = int(np.prod(node["w"].shape))
            if "/moe/".strip() and cfg.n_experts and key == "blocks" and "moe" in spec.path:
                n = n * cfg.top_k // cfg.n_experts
            pruned += n
        n_total -= int(pruned * cfg.hinm.vector_sparsity)

    # attention score/PV matmuls are real useful work (dominant for the
    # small-d long-S cells); 6ND alone misclassifies them as waste
    def attn_flops():
        hhd = cfg.n_heads * cfg.head_dim
        ctx = min(seq, cfg.window) if cfg.window else seq
        if cfg.family == "hybrid":
            l_attn = sum(1 for k_ in (cfg.block_pattern or ()) if k_ == "attn")
            l_attn = cfg.n_layers * l_attn // max(len(cfg.block_pattern or ()), 1)
        elif cfg.family == "ssm":
            # mLSTM/sLSTM recurrence: ~6 state ops of d x dk per token
            return 6.0 * cfg.n_layers * batch * seq * cfg.d_model * (
                cfg.d_model // cfg.n_heads)
        else:
            l_attn = cfg.n_layers
        if kind == "train":
            per = 3.0 * batch * seq * ctx * hhd  # causal half, fwd+bwd
            if cfg.family == "encdec":
                per += 6.0 * batch * seq * seq * hhd  # bidirectional encoder
            return l_attn * per
        if kind == "prefill":
            return l_attn * 2.0 * batch * seq * ctx * hhd
        return l_attn * 4.0 * batch * min(seq, ctx) * hhd  # decode vs cache

    if kind == "train":
        tokens = batch * seq
        if cfg.family == "encdec":
            tokens = batch * (seq + seq // 4)
        return 6.0 * n_total * tokens + attn_flops()
    if kind == "prefill":
        tokens = batch * seq
        return 2.0 * n_total * tokens + attn_flops()
    return 2.0 * n_total * batch + attn_flops()  # decode: one token per seq


def serve_decode_roofline(arch, batch: int = 64, ctx: int = 2048):
    """Analytic roofline rows for the serving decode inner loop.

    Two memory-bound comparisons on the TPU hardware model (decode moves
    bytes, not FLOPs — both rows are pure HBM-traffic terms):

    - **paged-attention**: per decode step the gather path reads the live
      KV pool AND materialises the `pool[bt]` contiguous view (one extra
      full write of the live rows) before attending; the Pallas kernel
      (kernels/paged_attn) streams pool pages through VMEM once. The
      saving is exactly the materialised copy's traffic.
    - **packed-decode**: weight bytes per step with every planned
      projection served dense vs PackedHiNM (exact packed sizes via
      eval_shape of `packing.pack`, metadata included) — the paper's
      weight-bandwidth win that `Scheduler(packed=...)` realises.

    Windowed (hybrid) configs cap the live context at the window; pure
    recurrent families have no paged-attention row. Cross-attention KV
    (encdec) is excluded — it is cached per slot, not paged.
    """
    import jax
    import numpy as np

    from repro.configs.base import load_arch
    from repro.core import packing
    from repro.models import module as mnn
    from repro.models import zoo
    from repro.train.abstract import _planned_paths, _get_container

    cfg = load_arch(arch)
    out = {"status": "ok", "arch": arch, "kind": "serve_decode",
           "batch": batch, "ctx": ctx}

    if zoo.supports_paged_attn_kernel(cfg):
        kvh, hd = cfg.n_kv_heads, cfg.head_dim
        itemsize = 2  # bf16 pools
        ctx_eff = min(ctx, cfg.window) if cfg.window else ctx
        if cfg.family == "hybrid" and cfg.block_pattern:
            n_attn = sum(1 for k in cfg.block_pattern if k == "attn")
            l_attn = cfg.n_layers * n_attn // len(cfg.block_pattern)
        else:
            l_attn = cfg.n_layers
        row_bytes = kvh * hd * 2 * itemsize + 4          # K + V + kpos
        kv_bytes = l_attn * batch * ctx_eff * row_bytes  # live rows, 1 pass
        gather_bytes = 2 * kv_bytes                      # + the copy write
        out["paged_attn"] = {
            "attn_layers": l_attn, "ctx_effective": ctx_eff,
            "kernel_bytes_per_step": kv_bytes,
            "gather_bytes_per_step": gather_bytes,
            "memory_term_kernel_s": kv_bytes / HBM_BW,
            "memory_term_gather_s": gather_bytes / HBM_BW,
            "traffic_saving": 1.0 - kv_bytes / gather_bytes,
        }

    pshape = jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0), cfg))
    total = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(pshape))
    planned_dense = planned_packed = 0
    for key, sel, spec in _planned_paths(cfg):
        w = mnn.get_path(_get_container(pshape, key, sel), spec.path)["w"]
        stack = int(np.prod(w.shape[:-2], dtype=np.int64)) if w.ndim > 2 else 1
        planned_dense += int(np.prod(w.shape)) * w.dtype.itemsize
        w2 = jax.ShapeDtypeStruct(w.shape[:-3:-1], w.dtype)  # (n_out, n_in)
        pk = jax.eval_shape(lambda a: packing.pack(a, cfg.hinm), w2)
        planned_packed += stack * sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(pk))
    dense_bytes = total
    packed_bytes = total - planned_dense + planned_packed
    out["packed_decode"] = {
        "dense_weight_bytes": dense_bytes,
        "packed_weight_bytes": packed_bytes,
        "bytes_ratio": packed_bytes / max(dense_bytes, 1),
        "memory_term_dense_s": dense_bytes / HBM_BW,
        "memory_term_packed_s": packed_bytes / HBM_BW,
    }
    return out


def serve_measured_attainment(bench_path: str = "BENCH_serve.json"):
    """Measured-vs-analytic roofline attainment for the serving decode loop.

    Restores the decode-step-time histogram `benchmarks/serve_bench.py`
    embeds in its report (telemetry subsystem snapshot format), rebuilds
    the analytic per-step HBM floor at the *bench* shape from the same
    report (one full packed-weight read plus one KV-pool pass per batched
    step), and reports attainment = analytic floor / measured percentile.
    Off-TPU the bench timings are host-interpreter numbers, so attainment
    is diagnostic there (~0); on TPU it is the fraction of the memory
    roofline the serving loop actually achieves. Returns None (silently)
    when no bench report exists — the column is optional.
    """
    if not os.path.exists(bench_path):
        return None
    try:
        with open(bench_path) as f:
            report = json.load(f)
        row = report["continuous"]
        snap = row["decode_step_hist"]
    except (ValueError, KeyError):
        return None
    from repro.serve.telemetry.metrics import histogram_from_snapshot

    hist = histogram_from_snapshot(snap)
    if hist.count == 0:
        return None
    bytes_per_step = row["packed_param_bytes"] + row["kv_pool_bytes"]
    analytic_s = bytes_per_step / HBM_BW
    p50, p99 = hist.percentile(50), hist.percentile(99)
    return {
        "status": "ok",
        "kind": "serve_decode_measured",
        "source": bench_path,
        "decode_steps_measured": hist.count,
        "measured_p50_step_s": p50,
        "measured_p99_step_s": p99,
        "measured_mean_step_s": hist.mean,
        "analytic_bytes_per_step": bytes_per_step,
        "analytic_memory_term_s": analytic_s,
        "attainment_p50": analytic_s / max(p50, 1e-12),
        "attainment_p99": analytic_s / max(p99, 1e-12),
    }


def _artifact_memory_bytes(arch, shape, dryrun_dir="experiments/dryrun"):
    """HBM traffic estimate from the REAL compiled artifact's buffers:
    every argument/output crosses HBM once, every temp twice (write+read).
    Fusion-realistic, unlike cost_analysis 'bytes accessed' which counts
    all per-op operand bytes on the unfused CPU backend."""
    fn = os.path.join(dryrun_dir, f"{arch}__{shape}__single_pod_16x16.json")
    if not os.path.exists(fn):
        return None
    with open(fn) as f:
        d = json.load(f)
    if d.get("status") != "ok":
        return None
    return (d["argument_bytes"] + d["output_bytes"] + 2 * d["temp_bytes"])


def analyze(arch, shape, mesh, devices):
    from repro.configs.base import load_arch
    from repro.launch.cells import shape_applicable

    from repro.launch import cells as cell_lib
    from repro.launch import hlo_stats

    cfg = load_arch(arch)
    skip = shape_applicable(cfg, shape)
    if skip:
        return {"status": "skipped", "reason": skip}
    stats = extrapolated_cell_stats(cfg, shape, mesh)
    mem_bytes = _artifact_memory_bytes(arch, shape)
    if mem_bytes is None:
        mem_bytes = stats["bytes"]
    # collectives from the FULL-DEPTH artifact (probes distort sharding
    # decisions): non-ENTRY collectives scale by the layer-loop trips
    cell = cell_lib.build_cell(cfg, shape, mesh)
    compiled = cell_lib.lower_cell(cell, mesh).compile()
    coll = hlo_stats.collective_bytes_nested(
        compiled.as_text(), cfg.n_layers // _period(cfg))
    stats["coll"] = coll["total_bytes"]
    compute_t = stats["flops"] / PEAK_FLOPS
    memory_t = mem_bytes / HBM_BW
    coll_t = stats["coll"] / ICI_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = stats["flops"] * devices
    advice = {
        "compute": "reduce redundant HLO FLOPs (remat/one-hot waste) or shard"
                   " more compute onto idle axes",
        "memory": "cut activation/weight HBM traffic: larger fused blocks,"
                  " packed HiNM weights, bf16 residuals",
        "collective": "overlap or shrink collectives: 2D-shard weights,"
                      " reduce-scatter instead of all-reduce, DP compression",
    }[dominant]
    return {
        "status": "ok",
        "arch": arch,
        "shape": shape,
        "flops_per_device": stats["flops"],
        "bytes_per_device": mem_bytes,
        "bytes_per_device_unfused_upper": stats["bytes"],
        "collective_bytes_per_device": stats["coll"],
        "compute_term_s": compute_t,
        "memory_term_s": memory_t,
        "collective_term_s": coll_t,
        "dominant": dominant,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_global,
        "useful_fraction": mf / max(hlo_global, 1.0),
        "roofline_bound_s": max(terms.values()),
        "mfu_upper_bound": (mf / devices / PEAK_FLOPS) / max(terms.values()),
        "advice": advice,
    }


def main():
    import jax

    from repro.configs.base import ARCH_IDS, SHAPES
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=False)
    devices = int(mesh.devices.size)
    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(args.out, exist_ok=True)

    print(f"{'cell':44s} {'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
          f"{'dominant':>10s} {'MFU_ub':>7s} {'useful':>7s}")
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}__{shape}"
            try:
                r = analyze(arch, shape, mesh, devices)
            except Exception as e:  # noqa: BLE001
                r = {"status": "failed", "error": repr(e)}
                print(f"{tag:44s} FAILED: {e!r}", flush=True)
            with open(os.path.join(args.out, tag + ".json"), "w") as fh:
                json.dump(r, fh, indent=1)
            if r["status"] == "ok":
                print(f"{tag:44s} {r['compute_term_s']:10.2e} "
                      f"{r['memory_term_s']:10.2e} {r['collective_term_s']:10.2e} "
                      f"{r['dominant']:>10s} {r['mfu_upper_bound']:7.3f} "
                      f"{r['useful_fraction']:7.3f}", flush=True)
            elif r["status"] == "skipped":
                print(f"{tag:44s} SKIP ({r['reason'][:40]})", flush=True)

    # serving decode rows: analytic memory terms for the paged-attention
    # kernel vs the gather path, and packed vs dense weight reads
    print(f"\n{'serve decode cell':44s} {'gather_s':>10s} {'kernel_s':>10s} "
          f"{'dense_s':>10s} {'packed_s':>10s} {'pack_ratio':>10s}")
    for arch in archs:
        tag = f"{arch}__serve_decode"
        try:
            r = serve_decode_roofline(arch)
        except Exception as e:  # noqa: BLE001
            r = {"status": "failed", "error": repr(e)}
            print(f"{tag:44s} FAILED: {e!r}", flush=True)
        with open(os.path.join(args.out, tag + ".json"), "w") as fh:
            json.dump(r, fh, indent=1)
        if r["status"] == "ok":
            pa, pd = r.get("paged_attn"), r["packed_decode"]
            print(f"{tag:44s} "
                  f"{pa['memory_term_gather_s'] if pa else float('nan'):10.2e} "
                  f"{pa['memory_term_kernel_s'] if pa else float('nan'):10.2e} "
                  f"{pd['memory_term_dense_s']:10.2e} "
                  f"{pd['memory_term_packed_s']:10.2e} "
                  f"{pd['bytes_ratio']:10.3f}", flush=True)

    # measured attainment at the bench shape, when serve_bench has run:
    # the step-time histogram the bench report embeds vs the analytic
    # per-step HBM floor for its packed weights + KV pool
    m = serve_measured_attainment()
    if m is not None:
        with open(os.path.join(args.out, "serve_decode_measured.json"),
                  "w") as fh:
            json.dump(m, fh, indent=1)
        print(f"\n{'serve decode measured (BENCH_serve.json)':44s} "
              f"p50={m['measured_p50_step_s']:.2e}s "
              f"p99={m['measured_p99_step_s']:.2e}s "
              f"analytic={m['analytic_memory_term_s']:.2e}s "
              f"attainment_p50={m['attainment_p50']:.3f}", flush=True)


if __name__ == "__main__":
    main()
