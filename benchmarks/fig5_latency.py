"""Figure 5 analogue — runtime overhead of gyro-permutation.

The paper's claim: the permuted vec_idx adds NO latency because the kernel
performs the indexed gather anyway. We measure the HiNM SpMM with
(a) identity vec_idx (unpermuted) vs (b) gyro-permuted vec_idx, on both
the XLA fast path (jit, CPU wall-clock) and the Pallas kernel in interpret
mode, across sparsity ratios and vector sizes — the delta should be noise.
Also reports packed/dense weight-byte ratio (the TPU bandwidth win).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, structured_weights, time_us
from repro.core import packing
from repro.core.gyro import gyro_permute
from repro.core.types import HiNMConfig
from repro.kernels import ops


def run() -> None:
    rng = np.random.default_rng(0)
    b, n_out, n_in = 64, 768, 768
    x = jnp.asarray(rng.normal(size=(b, n_in)).astype(np.float32))
    for sv, total in ((1.0 / 3.0, 2.0 / 3.0), (0.5, 0.75), (0.75, 0.875)):
        for v in (32, 64):
            cfg = HiNMConfig(v=v, n=2, m=4, vector_sparsity=sv)
            w = structured_weights(rng, n_out, n_in)
            sal = np.abs(w)
            gy = gyro_permute(sal, cfg, ocp_iters=6, icp_iters=6,
                              rng=np.random.default_rng(1))
            w_p = jnp.asarray(w[gy.out_perm])
            p_ident = packing.pack(w_p, cfg)                        # ascending order
            p_gyro = packing.pack(w_p, cfg,
                                  col_ids=jnp.asarray(gy.col_order),
                                  sal=jnp.asarray(sal[gy.out_perm]))

            f = jax.jit(lambda xx, pp: ops.hinm_matmul(xx, pp, backend="xla"),
                        static_argnames=())
            t_ident = time_us(lambda: f(x, p_ident).block_until_ready(), repeat=20)
            t_gyro = time_us(lambda: f(x, p_gyro).block_until_ready(), repeat=20)
            ratio = p_gyro.packed_bytes() / p_gyro.dense_bytes()
            emit(
                f"fig5_latency_s{int(total*100)}_v{v}",
                t_gyro,
                f"identity_us={t_ident:.1f};overhead_pct="
                f"{100*(t_gyro-t_ident)/max(t_ident,1e-9):.1f};"
                f"weight_bytes_ratio={ratio:.3f}",
            )


if __name__ == "__main__":
    run()
