"""Table 3 — ablation: HiNM (full gyro) vs HiNM-V1 (OVW-style OCP + our
ICP) vs HiNM-V2 (our OCP + Apex-style swap ICP), retained saliency on
ResNet-shaped magnitude matrices at 75% sparsity."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, structured_weights
from repro.core import baselines
from repro.core.gyro import gyro_permute
from repro.core.types import HiNMConfig

SHAPES = [(128, 1152), (256, 2304)]


def run() -> None:
    rng = np.random.default_rng(0)
    cfg = HiNMConfig(v=32, n=2, m=4, vector_sparsity=0.5)
    acc = {"hinm": [], "v1": [], "v2": []}
    times = {"hinm": 0.0, "v1": 0.0, "v2": 0.0}
    for shape in SHAPES:
        sal = np.abs(structured_weights(rng, *shape))
        for name, fn in (
            ("hinm", lambda: gyro_permute(sal, cfg, ocp_iters=10, icp_iters=8,
                                          rng=np.random.default_rng(1))),
            ("v1", lambda: baselines.hinm_v1(sal, cfg, np.random.default_rng(1))),
            ("v2", lambda: baselines.hinm_v2(sal, cfg, np.random.default_rng(1),
                                             ocp_iters=10)),
        ):
            t0 = time.perf_counter()
            res = fn()
            times[name] += (time.perf_counter() - t0) * 1e6
            acc[name].append(res.retained_fraction)
    for k in acc:
        emit(f"table3_ablation_{k}", times[k] / len(SHAPES),
             f"retained_frac={np.mean(acc[k]):.4f}")


if __name__ == "__main__":
    run()
