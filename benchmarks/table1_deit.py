"""Table 1 analogue — DeiT-base one-shot pruning with second-order saliency.

DeiT-base Linear shapes (attention + MLP), rho = w^2 * diag(F) with a
synthetic diagonal Fisher (per-row/column scaled, as gradient statistics
are in practice). Reports retained second-order saliency for HiNM (gyro)
vs HiNM-NoPerm at 65/75/85% — the Table-1 accuracy ordering is driven by
exactly this quantity; the CAP (element-wise SOTA) proxy is the
unstructured retention at equal sparsity.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, structured_weights
from repro.core import baselines
from repro.core.gyro import gyro_permute
from repro.core.types import HiNMConfig
from benchmarks.fig3_fig4_oneshot import vector_sparsity_for

SHAPES = [(768, 768), (3072, 768), (768, 3072)]  # qkv/out, fc1, fc2


def run() -> None:
    rng = np.random.default_rng(0)
    for total in (0.65, 0.75, 0.85):
        cfg = HiNMConfig(v=32, n=2, m=4,
                         vector_sparsity=vector_sparsity_for(total))
        res = {"hinm": [], "noperm": [], "cap_proxy": []}
        for shape in SHAPES:
            w = structured_weights(rng, *shape)
            fisher = np.abs(structured_weights(rng, *shape))  # synthetic diag F
            sal = (w ** 2) * fisher
            gy = gyro_permute(sal, cfg, ocp_iters=8, icp_iters=8,
                              rng=np.random.default_rng(2))
            nop = gyro_permute(sal, cfg, rng=np.random.default_rng(2),
                               run_ocp=False, run_icp=False)
            res["hinm"].append(gy.retained_fraction)
            res["noperm"].append(nop.retained_fraction)
            res["cap_proxy"].append(baselines.unstructured_retained(sal, total))
        for k, v in res.items():
            emit(f"table1_deit_{int(total*100)}pct_{k}", 0.0,
                 f"retained_frac={np.mean(v):.4f}")


if __name__ == "__main__":
    run()
